//! # The serving control plane (scenario-sharded batching, admission,
//! latency/SLO accounting)
//!
//! EdgeOL's deployment premise is *in-situ online learning*: one edge
//! accelerator both serves streaming inference requests and fine-tunes the
//! deployed model.  The seed implementation executed one fixed-shape
//! artifact per request with no notion of queueing, latency, or contention
//! with fine-tuning rounds.  This module is the subsystem between the
//! event stream and [`crate::model::ModelSession`] — since PR 5 an
//! *event-driven control plane* (`on_arrival`/`poll` instead of the old
//! push-based `submit`/`pump`/`drain`), reusable as a library API:
//!
//! * [`admission`] — [`Admission`] verdicts under a shedding policy
//!   (`--max-queue` depth cap, optional SLO-infeasibility drop) and the
//!   [`AdmissionPolicy`] queue ordering (`--queue-policy fifo|edf`:
//!   earliest-deadline-first across scenarios);
//! * [`queue`] — pending requests with arrival times, deadlines, and their
//!   already-drawn test rows (sampling at arrival keeps the world RNG
//!   stream in event order), with positional access for policy pops;
//! * [`batcher`] — coalesces queued requests (scenarios may mix) into one
//!   padded `[batch_infer, d]` execute within a virtual-time window, and
//!   scatters per-request predictions/energy scores back out;
//! * [`banks`] — the [`BankSet`]: an LRU-bounded map of scenario →
//!   resident bank-installed serving θ (warm-packed on install, released
//!   on eviction), so mixed-scenario bursts share executes with zero
//!   serving rebuilds after warm-up;
//! * [`latency`] — queueing delay + batched service time priced through
//!   [`crate::cost::device::DeviceModel`]; global and per-scenario
//!   p50/p95/p99 digests, SLO-violation and deadline-miss counts;
//! * [`scheduler`] — arbitrates the single device between fine-tuning
//!   rounds and inference bursts: requests arriving mid-round pay the
//!   delay, and a triggered round can be deferred under backlog (bounded
//!   by a starvation cap), feeding LazyTune's request-pressure term a real
//!   queue depth;
//! * [`engine`] — the control plane itself: [`ServeEngine::on_arrival`]
//!   admits or sheds, [`ServeEngine::poll`] advances virtual time and
//!   returns [`ServeEvent`]s;
//! * [`router`] + [`fleet`] — since PR 8, a [`FleetRouter`] fronting N
//!   independent engines: scenario-affinity routing with least-loaded
//!   fallback, queue-full verdicts consumed as cross-engine shedding
//!   hints, and hot-scenario rebalancing via proactive bank installs
//!   (`--fleet N`); outputs merge in engine-id order, so fleet reports
//!   and timelines are worker-count independent.
//!
//! **Determinism contract:** everything here runs in virtual time off the
//! seeded event stream.  The default configuration — FIFO, no queue cap,
//! `batch_window_s == 0` — serves every request alone in arrival order
//! with a full-draw batch, so reports are bit-identical to the
//! pre-control-plane serving path (enforced by `tests/serving_engine.rs`);
//! the latency/batch/drop fields are serving-side instrumentation,
//! excluded from [`crate::metrics::Report::fingerprint`] like the other
//! perf counters.

pub mod admission;
pub mod banks;
pub mod batcher;
pub mod engine;
pub mod fleet;
pub mod latency;
pub mod queue;
pub mod recovery;
pub mod router;
pub mod scheduler;

pub use admission::{
    Admission, AdmissionPolicy, DropReason, QueuePolicyKind, ShedPolicy,
};
pub use banks::{BankInstall, BankSet, MAX_BANK_CAPACITY};
pub use batcher::{AdaptiveBatcher, BatchSpan, PaddedBatch};
pub use engine::{ServeCtx, ServeEngine, ServeEvent, ServedRequest};
pub use fleet::{
    engine_fault_seed, run_pool, FaultScope, Fleet, FleetConfig,
    FleetCounters, FleetPoolSpec, FleetYield,
};
pub use latency::{LatencyModel, LatencySummary};
pub use queue::{QueuedRequest, RequestQueue};
pub use recovery::{BreakerState, CircuitBreaker, RecoveryConfig, RetryPolicy};
pub use router::{
    FleetRouter, RouteDecision, RouterConfig, RouterCounters,
};
pub use scheduler::{RoundDecision, Scheduler};

/// Serving-engine knobs (part of [`crate::sim::RunConfig`]).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Virtual-time coalescing window, seconds.  `0.0` (the default)
    /// degenerates to one-request batches: bit-identical reports to the
    /// pre-engine serving path.
    pub batch_window_s: f64,
    /// Latency SLO in milliseconds.  Always accounted; requests are only
    /// ever dropped under the explicit shedding knobs below.
    pub slo_ms: f64,
    /// Rows drawn per request.  `None` (the default) keeps the seed's
    /// full `batch_infer` draw when the window is 0 and picks
    /// `batch_infer / 8` (≥ 1) when a real window is set; `Some(r)`
    /// forces `r` (clamped to the batch capacity).  Ignored entirely in
    /// `--no-batching` mode, which always uses the full draw.
    pub rows_per_request: Option<usize>,
    /// Queue depth at which the scheduler defers a triggered round
    /// (`0` = never defer).
    pub defer_backlog: usize,
    /// Starvation guard: max consecutive round deferrals.
    pub max_defers: u32,
    /// Queue ordering: FIFO (the default, the seed order) or EDF
    /// (earliest-deadline-first across scenarios).
    pub queue_policy: QueuePolicyKind,
    /// Drop arrivals once the queue holds this many requests
    /// (`--max-queue`; 0 = unbounded, the default).
    pub max_queue: usize,
    /// Drop arrivals whose deadline cannot be met even if served ahead of
    /// everything queued (`--shed-infeasible`; off by default).
    pub shed_infeasible: bool,
    /// Resident serving-θ banks (`--bank-capacity`, LRU-evicted beyond
    /// this; clamped to ≥ 1 and to a ceiling that keeps all banks plus
    /// the live θ inside the session's θ-value cache — see
    /// `serve::banks::MAX_BANK_CAPACITY`).  With capacity ≥ active
    /// scenarios a mixed-scenario burst never rebuilds serving θ after
    /// warm-up.
    pub bank_capacity: usize,
    /// Fault recovery: retry/backoff, circuit breaker, degraded serving
    /// (see [`recovery::RecoveryConfig`]).  Enabled by default — with no
    /// faults injected the recovery state never changes, so the healthy
    /// path and its fingerprint are untouched.
    pub recovery: RecoveryConfig,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            batch_window_s: 0.0,
            slo_ms: 250.0,
            rows_per_request: None,
            defer_backlog: 4,
            max_defers: 2,
            queue_policy: QueuePolicyKind::Fifo,
            max_queue: 0,
            shed_infeasible: false,
            bank_capacity: 4,
            recovery: RecoveryConfig::default(),
        }
    }
}

impl ServeConfig {
    pub fn slo_s(&self) -> f64 {
        self.slo_ms / 1e3
    }

    /// Resolve the per-request row draw for an artifact batch capacity.
    pub fn rows_per_request(&self, batch_infer: usize) -> usize {
        match self.rows_per_request {
            Some(r) => r.clamp(1, batch_infer),
            None if self.batch_window_s > 0.0 => (batch_infer / 8).max(1),
            None => batch_infer,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_config_is_the_degenerate_identity_mode() {
        let c = ServeConfig::default();
        assert_eq!(c.batch_window_s, 0.0);
        assert_eq!(c.rows_per_request(64), 64, "unbatched keeps the full draw");
        assert_eq!(c.queue_policy, QueuePolicyKind::Fifo);
        assert_eq!(c.max_queue, 0, "unbounded queue by default");
        assert!(!c.shed_infeasible, "no shedding by default");
    }

    #[test]
    fn batched_rows_default_to_an_eighth_of_capacity() {
        let mut c =
            ServeConfig { batch_window_s: 10.0, ..ServeConfig::default() };
        assert_eq!(c.rows_per_request(64), 8);
        assert_eq!(c.rows_per_request(4), 1);
        c.rows_per_request = Some(999);
        assert_eq!(c.rows_per_request(64), 64, "clamped to capacity");
    }
}
