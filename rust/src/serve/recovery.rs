//! Fault recovery for the serving plane: retry with exponential backoff
//! (in *virtual* time) and a per-backend circuit breaker.
//!
//! Under injected faults (`runtime/faults.rs`) a batch execute can fail
//! transiently (retry wins), persistently (retries burn attempts), or the
//! backend can wedge outright.  The engine wires these pieces together:
//!
//! * [`RetryPolicy`] — up to `max_attempts` tries per batch, each retry
//!   pushing the batch's due time back by an exponentially growing
//!   backoff.  Backoff is charged through the virtual clock (the delayed
//!   due time feeds `Scheduler::admit_serve`), never wall time.
//! * [`CircuitBreaker`] — classic closed → open → half-open:
//!   `breaker_threshold` consecutive batch failures open the circuit;
//!   while open the engine stops attempting executes and **degrades** —
//!   serving from the stale resident bank (marked `degraded` on the
//!   [`crate::metrics::RequestRecord`]) or shedding with
//!   `Dropped{backend-unavailable}` when no bank is resident; after
//!   `breaker_cooldown_s` virtual seconds one half-open probe batch is
//!   allowed through, and its outcome closes or re-opens the circuit.
//!
//! Every transition is a pure function of virtual time and the (seeded)
//! fault sequence, so recovery behaviour is bit-reproducible across runs
//! and sweep worker counts.  With no faults injected none of this state
//! ever changes, and the default config's report fingerprint is identical
//! to a build without the recovery layer.

/// Recovery knobs (part of [`crate::serve::ServeConfig`]).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RecoveryConfig {
    /// Master switch.  `true` (the default) absorbs batch failures into
    /// retry/degrade/shed; `false` propagates the first execute error up
    /// through `ServeEngine::poll` exactly as before this layer existed.
    pub enabled: bool,
    /// Total attempts per batch (first try + retries).
    pub max_attempts: u32,
    /// Backoff before the first retry, in virtual milliseconds.
    pub backoff_ms: f64,
    /// Multiplier applied to the backoff after each failed attempt.
    pub backoff_mult: f64,
    /// Consecutive batch failures that trip the breaker open.
    pub breaker_threshold: u32,
    /// Virtual seconds the breaker stays open before a half-open probe.
    pub breaker_cooldown_s: f64,
    /// While the breaker is open, serve from the stale resident bank
    /// (marked degraded) instead of shedding everything.
    pub degraded_serving: bool,
}

impl Default for RecoveryConfig {
    fn default() -> RecoveryConfig {
        RecoveryConfig {
            enabled: true,
            max_attempts: 3,
            backoff_ms: 10.0,
            backoff_mult: 2.0,
            breaker_threshold: 3,
            breaker_cooldown_s: 30.0,
            degraded_serving: true,
        }
    }
}

impl RecoveryConfig {
    pub fn retry(&self) -> RetryPolicy {
        RetryPolicy {
            max_attempts: self.max_attempts.max(1),
            backoff_s: self.backoff_ms / 1e3,
            mult: self.backoff_mult.max(1.0),
        }
    }

    pub fn breaker(&self) -> CircuitBreaker {
        CircuitBreaker::new(
            self.breaker_threshold.max(1),
            self.breaker_cooldown_s.max(0.0),
        )
    }
}

/// Bounded retry with exponential backoff in virtual time.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    pub max_attempts: u32,
    backoff_s: f64,
    mult: f64,
}

impl RetryPolicy {
    /// Virtual-time backoff before retry number `retry` (1-based): the
    /// first retry waits `backoff_s`, the second `backoff_s * mult`, …
    pub fn backoff_s(&self, retry: u32) -> f64 {
        debug_assert!(retry >= 1);
        self.backoff_s * self.mult.powi(retry as i32 - 1)
    }

    /// Cumulative backoff charged once `retry` retries have happened.
    pub fn total_backoff_s(&self, retries: u32) -> f64 {
        (1..=retries).map(|r| self.backoff_s(r)).sum()
    }
}

/// Circuit state: closed (normal), open (degrading), half-open (one
/// probe in flight).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(&self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// Per-backend circuit breaker over batch outcomes (virtual-time clocked).
#[derive(Clone, Copy, Debug)]
pub struct CircuitBreaker {
    state: BreakerState,
    threshold: u32,
    cooldown_s: f64,
    consecutive_failures: u32,
    opened_at: f64,
    trips: u64,
}

impl CircuitBreaker {
    pub fn new(threshold: u32, cooldown_s: f64) -> CircuitBreaker {
        CircuitBreaker {
            state: BreakerState::Closed,
            threshold: threshold.max(1),
            cooldown_s,
            consecutive_failures: 0,
            opened_at: 0.0,
            trips: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Times the breaker transitioned into `Open` (including half-open
    /// probes that failed and re-opened it).
    pub fn trips(&self) -> u64 {
        self.trips
    }

    /// May an execute be attempted at virtual time `now`?  While open,
    /// returns `false` until the cooldown elapses, then transitions to
    /// half-open and admits exactly one probe.
    pub fn allow(&mut self, now: f64) -> bool {
        match self.state {
            BreakerState::Closed | BreakerState::HalfOpen => true,
            BreakerState::Open => {
                if now - self.opened_at >= self.cooldown_s {
                    self.state = BreakerState::HalfOpen;
                    true
                } else {
                    false
                }
            }
        }
    }

    /// A batch (or half-open probe) succeeded: close and reset.
    pub fn on_success(&mut self) {
        self.state = BreakerState::Closed;
        self.consecutive_failures = 0;
    }

    /// A batch exhausted its retries (or the half-open probe failed) at
    /// virtual time `now`.
    pub fn on_failure(&mut self, now: f64) {
        self.consecutive_failures += 1;
        let reopen = self.state == BreakerState::HalfOpen
            || self.consecutive_failures >= self.threshold;
        if reopen && self.state != BreakerState::Open {
            self.state = BreakerState::Open;
            self.opened_at = now;
            self.trips += 1;
        }
    }

    /// Current failure streak (0 after any success).
    pub fn consecutive_failures(&self) -> u32 {
        self.consecutive_failures
    }

    /// Checkpoint the breaker's mutable state (threshold/cooldown are
    /// configuration and rebuilt from the run config on restore).
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.u8(match self.state {
            BreakerState::Closed => 0,
            BreakerState::Open => 1,
            BreakerState::HalfOpen => 2,
        });
        w.u32(self.consecutive_failures);
        w.f64(self.opened_at);
        w.u64(self.trips);
    }

    /// Restore state saved by [`CircuitBreaker::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        self.state = match r.u8()? {
            0 => BreakerState::Closed,
            1 => BreakerState::Open,
            2 => BreakerState::HalfOpen,
            t => anyhow::bail!("bad breaker state tag {t}"),
        };
        self.consecutive_failures = r.u32()?;
        self.opened_at = r.f64()?;
        self.trips = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_grows_exponentially() {
        let r = RecoveryConfig::default().retry();
        assert_eq!(r.max_attempts, 3);
        assert!((r.backoff_s(1) - 0.010).abs() < 1e-12);
        assert!((r.backoff_s(2) - 0.020).abs() < 1e-12);
        assert!((r.backoff_s(3) - 0.040).abs() < 1e-12);
        assert!((r.total_backoff_s(2) - 0.030).abs() < 1e-12);
        assert_eq!(r.total_backoff_s(0), 0.0);
    }

    #[test]
    fn breaker_walks_closed_open_halfopen_closed() {
        let mut b = CircuitBreaker::new(3, 30.0);
        assert_eq!(b.state(), BreakerState::Closed);
        assert!(b.allow(0.0));
        b.on_failure(1.0);
        b.on_failure(2.0);
        assert_eq!(b.state(), BreakerState::Closed, "below threshold");
        b.on_failure(3.0);
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.trips(), 1);
        assert!(!b.allow(10.0), "cooling down");
        assert!(b.allow(33.0), "half-open probe admitted");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.on_success();
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn failed_probe_reopens_immediately() {
        let mut b = CircuitBreaker::new(3, 30.0);
        for t in 0..3 {
            b.on_failure(t as f64);
        }
        assert!(b.allow(31.0));
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.on_failure(31.5);
        assert_eq!(b.state(), BreakerState::Open, "one probe failure reopens");
        assert_eq!(b.trips(), 2);
        assert!(!b.allow(32.0));
        assert!(b.allow(61.5 + 1e-9), "cooldown restarts from reopen");
    }

    #[test]
    fn success_resets_the_failure_streak() {
        let mut b = CircuitBreaker::new(2, 5.0);
        b.on_failure(0.0);
        b.on_success();
        b.on_failure(1.0);
        assert_eq!(b.state(), BreakerState::Closed, "streak was reset");
        b.on_failure(2.0);
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn probe_failing_exactly_at_the_cooldown_boundary_restarts_cooldown() {
        let mut b = CircuitBreaker::new(2, 30.0);
        b.on_failure(5.0);
        b.on_failure(10.0); // trips open at t=10
        assert_eq!(b.state(), BreakerState::Open);
        // exactly at the boundary (now - opened_at == cooldown): the probe
        // is admitted — the comparison is >=, not >.
        assert!(b.allow(40.0), "probe admitted exactly at the boundary");
        assert_eq!(b.state(), BreakerState::HalfOpen);
        b.on_failure(40.0); // probe fails at that same instant
        assert_eq!(b.state(), BreakerState::Open, "probe failure reopens");
        assert_eq!(b.trips(), 2);
        // the cooldown restarted from the re-open time (40.0), not from
        // the original trip: just shy of the fresh boundary stays shut...
        assert!(!b.allow(69.999), "fresh cooldown still running");
        // ...and the fresh boundary admits the next probe.
        assert!(b.allow(70.0));
        assert_eq!(b.state(), BreakerState::HalfOpen);
    }

    #[test]
    fn probe_success_zeroes_the_failure_streak() {
        let mut b = CircuitBreaker::new(3, 10.0);
        for t in 0..3 {
            b.on_failure(t as f64); // trips at t=2
        }
        assert_eq!(b.state(), BreakerState::Open);
        assert_eq!(b.consecutive_failures(), 3);
        assert!(b.allow(12.0));
        b.on_success(); // probe succeeded
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.consecutive_failures(), 0, "streak fully reset");
        // a fresh streak must need the full threshold again
        b.on_failure(13.0);
        b.on_failure(14.0);
        assert_eq!(b.state(), BreakerState::Closed, "2 of 3 after reset");
        b.on_failure(15.0);
        assert_eq!(b.state(), BreakerState::Open);
    }

    #[test]
    fn breaker_checkpoint_round_trips_mid_open() {
        let mut b = CircuitBreaker::new(2, 30.0);
        b.on_failure(5.0);
        b.on_failure(10.0);
        assert_eq!(b.state(), BreakerState::Open);
        let mut w = crate::ckpt::ByteWriter::new();
        b.ckpt_save(&mut w);
        let buf = w.into_vec();
        let mut fresh = CircuitBreaker::new(2, 30.0);
        let mut r = crate::ckpt::ByteReader::new(&buf);
        fresh.ckpt_load(&mut r).unwrap();
        assert_eq!(fresh.state(), BreakerState::Open);
        assert_eq!(fresh.trips(), 1);
        assert_eq!(fresh.consecutive_failures(), 2);
        assert!(!fresh.allow(20.0), "opened_at restored: still cooling");
        assert!(fresh.allow(40.0), "cooldown measured from restored time");
    }

    #[test]
    fn default_config_is_enabled_but_inert_without_faults() {
        let c = RecoveryConfig::default();
        assert!(c.enabled);
        assert!(c.degraded_serving);
        // with no failures ever reported, allow() is always true and no
        // state changes — the healthy path is untouched.
        let mut b = c.breaker();
        for t in 0..100 {
            assert!(b.allow(t as f64));
        }
        assert_eq!(b.trips(), 0);
    }
}
