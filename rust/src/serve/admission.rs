//! Admission control for the serving plane: whether an arriving request
//! enters the queue at all, and in what order queued requests are taken
//! into batches.
//!
//! The seed engine admitted everything and served strictly FIFO; under
//! overload that turns every queued request into an SLO miss.  The control
//! plane splits the decision into a [`ShedPolicy`] (load shedding: a hard
//! `--max-queue` depth cap and an optional SLO-infeasibility test — a
//! request whose deadline cannot be met even if served ahead of everything
//! queued is dropped at arrival instead of wasting an execute) and an
//! [`AdmissionPolicy`] ordering (`--queue-policy fifo|edf`).
//!
//! **Determinism contract:** both policies are pure functions of the queue
//! contents and virtual time.  FIFO picks the front; EDF picks the
//! earliest `deadline_t` with ties broken by queue position (so with a
//! uniform SLO — every deadline `arrival + slo` — EDF orders exactly like
//! FIFO, and the default configuration stays bit-identical to the seed).

use super::queue::{QueuedRequest, RequestQueue};

/// Outcome of [`crate::serve::ServeEngine::on_arrival`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// The request entered the queue and will be served by a later poll.
    Accepted,
    /// The request was shed at arrival; no execute will ever run for it.
    Dropped { reason: DropReason },
}

/// Why a request was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DropReason {
    /// The queue already holds `max_queue` requests.
    QueueFull,
    /// Even served ahead of everything queued, the request could not
    /// finish by its deadline (`earliest completion > deadline_t`).
    SloInfeasible,
    /// The circuit breaker is open and no stale resident bank could serve
    /// the request (see [`crate::serve::recovery`]).  Unlike the other
    /// reasons this is decided at serve time, not arrival time.
    BackendUnavailable,
}

impl DropReason {
    pub fn name(&self) -> &'static str {
        match self {
            DropReason::QueueFull => "queue-full",
            DropReason::SloInfeasible => "slo-infeasible",
            DropReason::BackendUnavailable => "backend-unavailable",
        }
    }
}

/// Load-shedding knobs shared by every ordering policy.
#[derive(Clone, Copy, Debug, Default)]
pub struct ShedPolicy {
    /// Drop arrivals once the queue holds this many requests (0 = never,
    /// the default: the seed's unbounded queue).
    pub max_queue: usize,
    /// Drop arrivals whose deadline is already infeasible (off by
    /// default).
    pub shed_infeasible: bool,
}

/// Ordering + admission policy of the serving queue.
///
/// Object-safe so the engine can hold `Box<dyn AdmissionPolicy>` selected
/// at runtime from [`QueuePolicyKind`]; implementations must be pure
/// (no interior state) so replaying the same arrival trace reproduces the
/// same decisions.
pub trait AdmissionPolicy {
    /// Short identifier (`"fifo"` / `"edf"`) for reports and flags.
    fn name(&self) -> &'static str;

    /// Index (into the queue, position order) of the next request to pop
    /// into a batch; `None` on an empty queue.
    fn next_index(&self, queue: &RequestQueue) -> Option<usize>;

    /// Admission decision for `req` arriving with `queue_len` requests
    /// already pending.  `earliest_done_t` is the soonest virtual time
    /// one execute could complete for this request if it were served
    /// ahead of everything queued (the optimistic bound — see
    /// [`crate::serve::Scheduler::earliest_completion`]).  The default
    /// shedding logic is shared by every ordering.
    fn admit(
        &self,
        req: &QueuedRequest,
        queue_len: usize,
        shed: &ShedPolicy,
        earliest_done_t: f64,
    ) -> Admission {
        if shed.max_queue > 0 && queue_len >= shed.max_queue {
            return Admission::Dropped { reason: DropReason::QueueFull };
        }
        if shed.shed_infeasible && earliest_done_t > req.deadline_t {
            return Admission::Dropped { reason: DropReason::SloInfeasible };
        }
        Admission::Accepted
    }
}

/// First-in-first-out: the seed ordering (and the default).
#[derive(Clone, Copy, Debug, Default)]
pub struct Fifo;

impl AdmissionPolicy for Fifo {
    fn name(&self) -> &'static str {
        "fifo"
    }

    fn next_index(&self, queue: &RequestQueue) -> Option<usize> {
        if queue.is_empty() {
            None
        } else {
            Some(0)
        }
    }
}

/// Earliest-deadline-first across scenarios: the next request popped is
/// the one whose `deadline_t` is smallest (ties: queue position, so a
/// uniform SLO degenerates to FIFO).
///
/// Selection delegates to the queue's lazy heap side-index
/// ([`RequestQueue::edf_next_index`]) so a deep-backlog flush is
/// amortized O(log n) per pop instead of the old full rescan's O(n) —
/// with decisions bit-identical to that naive scan (same strict-`<`
/// stable-tie order), pinned by `edf_matches_the_naive_scan` below.
/// The policy itself stays pure: the amortization state lives in the
/// queue, keyed off its own mutations.
#[derive(Clone, Copy, Debug, Default)]
pub struct Edf;

impl AdmissionPolicy for Edf {
    fn name(&self) -> &'static str {
        "edf"
    }

    fn next_index(&self, queue: &RequestQueue) -> Option<usize> {
        queue.edf_next_index()
    }
}

/// Which ordering policy to construct (`--queue-policy`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum QueuePolicyKind {
    Fifo,
    Edf,
}

impl QueuePolicyKind {
    pub fn parse(s: &str) -> anyhow::Result<QueuePolicyKind> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "fifo" => QueuePolicyKind::Fifo,
            "edf" => QueuePolicyKind::Edf,
            other => {
                anyhow::bail!("unknown queue policy {other:?} (expected fifo|edf)")
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            QueuePolicyKind::Fifo => "fifo",
            QueuePolicyKind::Edf => "edf",
        }
    }

    pub fn build(&self) -> Box<dyn AdmissionPolicy> {
        match self {
            QueuePolicyKind::Fifo => Box::new(Fifo),
            QueuePolicyKind::Edf => Box::new(Edf),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(t: f64, deadline_t: f64) -> QueuedRequest {
        QueuedRequest {
            arrival_t: t,
            deadline_t,
            scenario: 0,
            stale_batches: 0,
            x: vec![0.0; 4],
            y: vec![0],
            rows: 1,
        }
    }

    #[test]
    fn fifo_always_picks_the_front() {
        let mut q = RequestQueue::new();
        assert_eq!(Fifo.next_index(&q), None);
        q.push(req(1.0, 9.0));
        q.push(req(2.0, 3.0));
        assert_eq!(Fifo.next_index(&q), Some(0));
    }

    #[test]
    fn edf_picks_the_earliest_deadline_with_stable_ties() {
        let mut q = RequestQueue::new();
        assert_eq!(Edf.next_index(&q), None);
        q.push(req(1.0, 9.0));
        q.push(req(2.0, 3.0)); // deadline-inverted: later arrival, earlier due
        q.push(req(3.0, 3.0)); // tie with index 1: position wins
        assert_eq!(Edf.next_index(&q), Some(1));
        // uniform SLO (deadline = arrival + const) degenerates to FIFO
        let mut u = RequestQueue::new();
        for t in [1.0, 2.0, 3.0] {
            u.push(req(t, t + 0.25));
        }
        assert_eq!(Edf.next_index(&u), Fifo.next_index(&u));
    }

    #[test]
    fn edf_matches_the_naive_scan() {
        // The pre-side-index implementation, kept verbatim as the oracle.
        fn naive(queue: &RequestQueue) -> Option<usize> {
            let mut best: Option<(usize, f64)> = None;
            for (i, r) in queue.iter().enumerate() {
                if best.is_none_or(|(_, d)| r.deadline_t < d) {
                    best = Some((i, r.deadline_t));
                }
            }
            best.map(|(i, _)| i)
        }
        let mut q = RequestQueue::new();
        let mut x = 11u64;
        for _ in 0..48 {
            x = x.wrapping_mul(2862933555777941757).wrapping_add(3037000493);
            q.push(req(0.0, 1.0 + ((x >> 40) % 8) as f64));
        }
        // full EDF drain, exactly like AdaptiveBatcher::take_batch pops
        while let Some(i) = Edf.next_index(&q) {
            assert_eq!(Some(i), naive(&q));
            q.remove(i);
        }
        assert_eq!(Edf.next_index(&q), None);
    }

    #[test]
    fn shedding_caps_the_queue_and_tests_feasibility() {
        let shed = ShedPolicy { max_queue: 2, shed_infeasible: true };
        let r = req(10.0, 10.5);
        // depth cap binds first
        assert_eq!(
            Fifo.admit(&r, 2, &shed, 10.2),
            Admission::Dropped { reason: DropReason::QueueFull }
        );
        // feasible: earliest completion inside the deadline
        assert_eq!(Fifo.admit(&r, 1, &shed, 10.4), Admission::Accepted);
        // infeasible: the device cannot finish in time even if idle
        assert_eq!(
            Fifo.admit(&r, 1, &shed, 10.6),
            Admission::Dropped { reason: DropReason::SloInfeasible }
        );
        // defaults shed nothing
        let open = ShedPolicy::default();
        assert_eq!(Fifo.admit(&r, 10_000, &open, 99.0), Admission::Accepted);
    }

    #[test]
    fn kind_parses_and_builds() {
        assert_eq!(QueuePolicyKind::parse("EDF").unwrap(), QueuePolicyKind::Edf);
        assert_eq!(QueuePolicyKind::parse("fifo").unwrap(), QueuePolicyKind::Fifo);
        assert!(QueuePolicyKind::parse("lifo").is_err());
        assert_eq!(QueuePolicyKind::Edf.build().name(), "edf");
    }
}
