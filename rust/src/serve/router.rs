//! Scenario-affinity routing across a fleet of serving engines.
//!
//! The [`FleetRouter`] is the pure decision core of the fleet layer
//! ([`super::fleet`]): given only its own bookkeeping — a per-engine
//! residency mirror, last-known queue depths, and per-engine per-scenario
//! queued counts — it picks the engine for each arriving request.  Pure
//! and deterministic by construction (no clocks, no randomness, every
//! tie broken by lowest engine id), so a replayed arrival trace
//! reproduces the same routing byte-for-byte regardless of whether the
//! engines behind it run inline or on worker threads.
//!
//! Three decisions live here:
//!
//! * **affinity** — send a request to an engine whose bank mirror already
//!   holds its scenario (among holders: least-loaded, then lowest id), so
//!   warm [`super::BankSet`] residency is reused instead of rebuilt;
//!   fall back to the least-loaded engine when no mirror holds it;
//! * **cross-engine shedding hints** — an [`Admission`] verdict of
//!   `Dropped{queue-full}` from the affinity target is a hint, not a
//!   drop: [`FleetRouter::retry_target`] names the least-loaded *other*
//!   engine to try before the request is truly shed;
//! * **rebalancing** — when one engine's share of the fleet-wide queued
//!   requests for a single scenario crosses
//!   [`RouterConfig::rebalance_threshold`], that scenario is hot:
//!   [`FleetRouter::maybe_rebalance`] names a second engine to install
//!   its bank on, spreading subsequent affinity routes.
//!
//! The residency mirror is the *router's* view, updated on routing
//! decisions and rebalance installs with the same LRU capacity the
//! engines use — like a real fleet's control plane it may lag the
//! engines' true `BankSet`s (an engine-side eviction is invisible here),
//! which only ever costs a cold-bank serve, never correctness.

use std::collections::BTreeMap;

use super::admission::{Admission, DropReason};

/// Fleet-routing knobs (carried by [`super::fleet::FleetConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct RouterConfig {
    /// Scenario-affinity routing on (the default).  Off = every request
    /// goes least-loaded, the ablation arm of the `repro fleet` table.
    pub affinity: bool,
    /// One engine's share of fleet-wide queued requests for a single
    /// scenario that marks the scenario hot (`--rebalance-threshold`;
    /// `0` disables rebalancing).
    pub rebalance_threshold: f64,
}

impl Default for RouterConfig {
    fn default() -> RouterConfig {
        RouterConfig { affinity: true, rebalance_threshold: 0.5 }
    }
}

/// Where [`FleetRouter::route`] sent a request, and why.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct RouteDecision {
    pub engine: usize,
    /// Chosen because the engine's bank mirror holds the scenario (the
    /// queue-full retry hint only applies to affinity routes).
    pub by_affinity: bool,
}

/// Fleet routing counters, exported into the report
/// (fingerprint-excluded, like every serving-side counter).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RouterCounters {
    pub routed_by_affinity: u64,
    pub routed_least_loaded: u64,
    pub cross_engine_retries: u64,
    pub rebalances: u64,
}

/// Deterministic scenario-affinity router over `n` engines.
#[derive(Debug)]
pub struct FleetRouter {
    cfg: RouterConfig,
    /// Mirror LRU capacity — matches the engines' `--bank-capacity`.
    bank_capacity: usize,
    /// Per-engine residency mirror in LRU order (index 0 = coldest).
    residency: Vec<Vec<usize>>,
    /// Last-known queue depth per engine ([`FleetRouter::note_depth`]).
    depths: Vec<usize>,
    /// Per-engine queued-request count per scenario: +1 on accept, -1 on
    /// departure (served, or shed at serve time).
    queued: Vec<BTreeMap<usize, usize>>,
    counters: RouterCounters,
}

impl FleetRouter {
    pub fn new(n: usize, bank_capacity: usize, cfg: RouterConfig) -> FleetRouter {
        let n = n.max(1);
        FleetRouter {
            cfg,
            bank_capacity: bank_capacity.max(1),
            residency: vec![Vec::new(); n],
            depths: vec![0; n],
            queued: vec![BTreeMap::new(); n],
            counters: RouterCounters::default(),
        }
    }

    pub fn n(&self) -> usize {
        self.depths.len()
    }

    pub fn counters(&self) -> RouterCounters {
        self.counters
    }

    /// Least-loaded engine by last-known depth, lowest id on ties,
    /// optionally excluding one engine.  `None` only when every engine
    /// is excluded (n == 1 with an exclusion).
    fn least_loaded(&self, exclude: Option<usize>) -> Option<usize> {
        self.depths
            .iter()
            .enumerate()
            .filter(|&(e, _)| Some(e) != exclude)
            .min_by_key(|&(e, &d)| (d, e))
            .map(|(e, _)| e)
    }

    /// Touch `scenario` in `engine`'s mirror: move it to most-recent,
    /// inserting (and evicting the coldest entry) if absent.
    fn touch(&mut self, engine: usize, scenario: usize) {
        let lru = &mut self.residency[engine];
        if let Some(i) = lru.iter().position(|&s| s == scenario) {
            lru.remove(i);
        } else if lru.len() >= self.bank_capacity {
            lru.remove(0);
        }
        lru.push(scenario);
    }

    /// Pick the engine for an arriving request of `scenario`.
    pub fn route(&mut self, scenario: usize) -> RouteDecision {
        if self.cfg.affinity {
            let holder = self
                .residency
                .iter()
                .enumerate()
                .filter(|(_, lru)| lru.contains(&scenario))
                .min_by_key(|&(e, _)| (self.depths[e], e))
                .map(|(e, _)| e);
            if let Some(engine) = holder {
                self.counters.routed_by_affinity += 1;
                self.touch(engine, scenario);
                return RouteDecision { engine, by_affinity: true };
            }
        }
        let engine = self.least_loaded(None).unwrap_or(0);
        self.counters.routed_least_loaded += 1;
        self.touch(engine, scenario);
        RouteDecision { engine, by_affinity: false }
    }

    /// Consume a `Dropped{queue-full}` verdict from the affinity target
    /// as a shedding hint: the least-loaded *other* engine to retry on
    /// (`None` when there is no other engine).  Any other verdict is
    /// final and must not be passed here.
    pub fn retry_target(
        &mut self,
        scenario: usize,
        verdict: Admission,
        from: usize,
    ) -> Option<usize> {
        if verdict != (Admission::Dropped { reason: DropReason::QueueFull }) {
            return None;
        }
        let alt = self.least_loaded(Some(from))?;
        self.counters.cross_engine_retries += 1;
        self.touch(alt, scenario);
        Some(alt)
    }

    /// A request of `scenario` was accepted by `engine`.
    pub fn on_accept(&mut self, engine: usize, scenario: usize) {
        self.depths[engine] += 1;
        *self.queued[engine].entry(scenario).or_insert(0) += 1;
    }

    /// A queued request of `scenario` left `engine`'s queue (served, or
    /// shed at serve time while the breaker was open).
    pub fn on_departure(&mut self, engine: usize, scenario: usize) {
        if let Some(c) = self.queued[engine].get_mut(&scenario) {
            *c -= 1;
            if *c == 0 {
                self.queued[engine].remove(&scenario);
            }
        }
    }

    /// Exact queue depth reported back from `engine` (after an arrival
    /// or poll) — overrides the router's running estimate.
    pub fn note_depth(&mut self, engine: usize, depth: usize) {
        self.depths[engine] = depth;
    }

    /// Check the hot-scenario condition: if one engine's queued share of
    /// a single scenario crossed the threshold, return `(scenario,
    /// target)` — the engine to install a second bank on (least-loaded
    /// among engines whose mirror lacks the scenario).  The target's
    /// mirror is updated here; the caller performs the actual warm
    /// install.  `None` when balanced, disabled, or every engine already
    /// holds the scenario.
    pub fn maybe_rebalance(&mut self) -> Option<(usize, usize)> {
        if self.cfg.rebalance_threshold <= 0.0 || self.n() < 2 {
            return None;
        }
        let total: usize =
            self.queued.iter().flat_map(|m| m.values()).sum();
        if total == 0 {
            return None;
        }
        // hottest (engine, scenario) cell; engine id then scenario order
        // break ties, so the scan is deterministic.
        let mut hot: Option<(usize, usize, usize)> = None; // (count, e, s)
        for (e, m) in self.queued.iter().enumerate() {
            for (&s, &c) in m {
                if hot.is_none_or(|(best, _, _)| c > best) {
                    hot = Some((c, e, s));
                }
            }
        }
        let (count, hot_engine, scenario) = hot?;
        // a lone queued request is 100% of itself — never "hot"
        if count < 2
            || (count as f64) <= self.cfg.rebalance_threshold * total as f64
        {
            return None;
        }
        let target = self
            .depths
            .iter()
            .enumerate()
            .filter(|&(e, _)| {
                e != hot_engine && !self.residency[e].contains(&scenario)
            })
            .min_by_key(|&(e, &d)| (d, e))
            .map(|(e, _)| e)?;
        self.counters.rebalances += 1;
        self.touch(target, scenario);
        Some((scenario, target))
    }

    /// Checkpoint the router's bookkeeping (`cfg` and `bank_capacity` are
    /// configuration, rebuilt from the run config on restore).
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.usize(self.residency.len());
        for lru in &self.residency {
            w.usizes(lru);
        }
        w.usizes(&self.depths);
        w.usize(self.queued.len());
        for m in &self.queued {
            w.usize(m.len());
            for (&s, &c) in m {
                w.usize(s);
                w.usize(c);
            }
        }
        w.u64(self.counters.routed_by_affinity);
        w.u64(self.counters.routed_least_loaded);
        w.u64(self.counters.cross_engine_retries);
        w.u64(self.counters.rebalances);
    }

    /// Restore state saved by [`FleetRouter::ckpt_save`] into a router
    /// built for the same fleet size.
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        let n = r.usize()?;
        if n != self.residency.len() {
            anyhow::bail!(
                "checkpoint router has {n} engines, config has {}",
                self.residency.len()
            );
        }
        let mut residency = Vec::with_capacity(n);
        for _ in 0..n {
            residency.push(r.usizes()?);
        }
        self.residency = residency;
        self.depths = r.usizes()?;
        let n = r.usize()?;
        let mut queued = Vec::with_capacity(n);
        for _ in 0..n {
            let len = r.usize()?;
            let mut m = BTreeMap::new();
            for _ in 0..len {
                let s = r.usize()?;
                let c = r.usize()?;
                m.insert(s, c);
            }
            queued.push(m);
        }
        self.queued = queued;
        self.counters.routed_by_affinity = r.u64()?;
        self.counters.routed_least_loaded = r.u64()?;
        self.counters.cross_engine_retries = r.u64()?;
        self.counters.rebalances = r.u64()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn router(n: usize) -> FleetRouter {
        FleetRouter::new(n, 4, RouterConfig::default())
    }

    #[test]
    fn single_engine_routes_everything_to_engine_zero() {
        let mut r = router(1);
        for s in [0, 1, 2, 0] {
            assert_eq!(r.route(s).engine, 0);
            r.on_accept(0, s);
        }
        let full = Admission::Dropped { reason: DropReason::QueueFull };
        assert_eq!(r.retry_target(0, full, 0), None, "no other engine");
        assert_eq!(r.maybe_rebalance(), None, "needs at least two engines");
    }

    #[test]
    fn affinity_prefers_the_holder_else_least_loaded() {
        let mut r = router(3);
        // cold start: least-loaded tie -> engine 0, which then holds s7
        let d0 = r.route(7);
        assert_eq!((d0.engine, d0.by_affinity), (0, false));
        r.on_accept(0, 7);
        // s7 again: engine 0 holds it, even though it is now deeper
        let d1 = r.route(7);
        assert_eq!((d1.engine, d1.by_affinity), (0, true));
        // a different scenario goes least-loaded (engine 1: lowest id
        // among the empty engines)
        let d2 = r.route(8);
        assert_eq!((d2.engine, d2.by_affinity), (1, false));
        let c = r.counters();
        assert_eq!(c.routed_by_affinity, 1);
        assert_eq!(c.routed_least_loaded, 2);
    }

    #[test]
    fn affinity_off_is_pure_least_loaded() {
        let mut r =
            FleetRouter::new(2, 4, RouterConfig { affinity: false, ..RouterConfig::default() });
        assert_eq!(r.route(5).engine, 0);
        r.on_accept(0, 5);
        // engine 0 holds s5 in its mirror, but affinity is off
        let d = r.route(5);
        assert_eq!((d.engine, d.by_affinity), (1, false));
        assert_eq!(r.counters().routed_by_affinity, 0);
    }

    #[test]
    fn queue_full_verdict_retries_least_loaded_other_engine() {
        let mut r = router(3);
        r.note_depth(0, 8);
        r.note_depth(1, 3);
        r.note_depth(2, 5);
        let full = Admission::Dropped { reason: DropReason::QueueFull };
        assert_eq!(r.retry_target(4, full, 0), Some(1));
        assert_eq!(r.counters().cross_engine_retries, 1);
        // accepted and other dropped verdicts are final
        assert_eq!(r.retry_target(4, Admission::Accepted, 0), None);
        let infeasible =
            Admission::Dropped { reason: DropReason::SloInfeasible };
        assert_eq!(r.retry_target(4, infeasible, 0), None);
        assert_eq!(r.counters().cross_engine_retries, 1);
    }

    #[test]
    fn hot_scenario_installs_a_second_bank_once() {
        let mut r = router(2);
        // 3 of 4 fleet-queued requests are scenario 9 on engine 0
        r.route(9);
        r.on_accept(0, 9);
        r.on_accept(0, 9);
        r.on_accept(0, 9);
        r.on_accept(1, 2);
        r.note_depth(0, 3);
        r.note_depth(1, 1);
        assert_eq!(r.maybe_rebalance(), Some((9, 1)));
        assert_eq!(r.counters().rebalances, 1);
        // engine 1 now mirrors s9: no target is left, so no re-trigger
        assert_eq!(r.maybe_rebalance(), None);
        // and affinity now sees two holders; the shallower one wins
        assert_eq!(r.route(9).engine, 1);
    }

    #[test]
    fn departures_cool_the_scenario_below_threshold() {
        let mut r = router(2);
        r.on_accept(0, 3);
        r.on_accept(0, 3);
        r.on_accept(1, 4);
        r.on_accept(1, 5);
        // 2/4 == threshold 0.5: strictly-above required, stays balanced
        assert_eq!(r.maybe_rebalance(), None);
        r.on_departure(1, 4);
        // 2/3 > 0.5: hot now; target skips the hot engine itself
        assert_eq!(r.maybe_rebalance(), Some((3, 1)));
        r.on_departure(0, 3);
        r.on_departure(0, 3);
        assert_eq!(r.maybe_rebalance(), None, "drained scenario is cold");
    }

    #[test]
    fn mirror_is_lru_bounded_like_the_banks() {
        let mut r = FleetRouter::new(1, 2, RouterConfig::default());
        r.route(0);
        r.route(1);
        r.route(0); // touch: 0 becomes most-recent
        r.route(2); // evicts 1 (coldest), not 0
        assert_eq!(r.residency[0], vec![0, 2]);
    }
}
