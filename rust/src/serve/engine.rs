//! The serving engine: glue between the event stream and
//! [`ModelSession`].  Owns the request queue, the adaptive batcher, the
//! latency/SLO ledger, the tune-vs-serve scheduler, and the cached
//! bank-installed serving θ (moved here from `sim::run` — the serving
//! parameters are a serving-engine concern).
//!
//! The engine is backend-agnostic: every execute goes through the
//! session's [`crate::runtime::Backend`], so the same batched serving path
//! runs on PJRT artifacts and on the pure-Rust reference executor
//! (`tests/serving_engine.rs` asserts batch-composition independence on a
//! *really executing* backend in CI).
//!
//! Three operating modes, all seed-deterministic:
//!
//! * **direct** (`--no-batching`): every request executes immediately on
//!   arrival with a full `batch_infer`-row test draw — structurally the
//!   pre-engine request path, kept as the equivalence baseline;
//! * **window 0** (the default): requests route through the queue and
//!   batcher but every batch degenerates to one request — reports are
//!   bit-identical to the direct path (and to the pre-engine seed);
//! * **window > 0**: requests draw fewer rows, wait up to the virtual-time
//!   window, and consecutive same-scenario requests share one padded
//!   execute; per-request latency = queueing delay + batched service time.

use std::sync::OnceLock;

use anyhow::Result;

use crate::bitset::BitSet;
use crate::cost::device::DeviceModel;
use crate::data::benchmarks::Scenario;
use crate::model::{Cwr, ModelSession, Params};
use crate::runtime::artifact::ModelManifest;

use super::batcher::AdaptiveBatcher;
use super::latency::{LatencyModel, LatencySummary};
use super::queue::{QueuedRequest, RequestQueue};
use super::scheduler::Scheduler;
use super::ServeConfig;

/// `ETUNER_DEBUG` looked up once per process (it used to be a
/// `std::env::var_os` call on every request in the serving hot path).
fn debug_enabled() -> bool {
    static DEBUG: OnceLock<bool> = OnceLock::new();
    *DEBUG.get_or_init(|| std::env::var_os("ETUNER_DEBUG").is_some())
}

/// Cached bank-installed serving parameters + the generation snapshot they
/// were built from.  While the snapshot matches, serving reuses the cached
/// θ outright (no clone, no head surgery, and — via the session's literal
/// cache — no re-marshal).
struct ServingCache {
    params: Option<Params>,
    src_id: u64,
    src_gen: u64,
    cwr_gen: u64,
    scenario: usize,
    /// scratch: live-scenario classes excluded from the bank install.
    except: BitSet,
    rebuilds: u64,
    hits: u64,
}

impl ServingCache {
    fn new(classes: usize) -> ServingCache {
        ServingCache {
            params: None,
            src_id: 0,
            src_gen: 0,
            cwr_gen: 0,
            scenario: usize::MAX,
            except: BitSet::new(classes),
            rebuilds: 0,
            hits: 0,
        }
    }

    fn is_valid(&self, src: &Params, cwr: &Cwr, scenario: usize) -> bool {
        self.params.is_some()
            && self.src_id == src.id()
            && self.src_gen == src.generation()
            && self.cwr_gen == cwr.generation()
            && self.scenario == scenario
    }
}

/// One completed request, in service order.
#[derive(Clone, Copy, Debug)]
pub struct ServedRequest {
    pub arrival_t: f64,
    pub scenario: usize,
    pub accuracy: f32,
    /// Mean energy score `-logsumexp` over the request's rows (feeds the
    /// scenario-change detector in service order).
    pub energy_score: f64,
    pub stale_batches: usize,
    /// End-to-end latency: queueing delay + batched service time.
    pub latency_s: f64,
    /// Requests sharing this request's execute (1 = unbatched).
    pub batch_requests: usize,
    /// Requests still queued when this one was served.
    pub queue_depth: usize,
}

/// Serving engine state (one per simulation).
pub struct ServeEngine {
    batching: bool,
    rows_per_request: usize,
    slo_s: f64,
    batcher: AdaptiveBatcher,
    queue: RequestQueue,
    latency: LatencyModel,
    scheduler: Scheduler,
    serving: ServingCache,
    disable_serving_cache: bool,
    scratch: Vec<f32>,
    executes: u64,
    served: u64,
}

impl ServeEngine {
    pub fn new(
        m: &ModelManifest,
        device: &DeviceModel,
        cfg: &ServeConfig,
        direct: bool,
        disable_serving_cache: bool,
    ) -> ServeEngine {
        // `direct` is the only bypass: window 0 still routes through the
        // queue + batcher (each full-draw request fills the batch exactly,
        // so it flushes inside `submit` — bit-identical to direct serving,
        // but exercising the real pack/scatter machinery).
        let batching = !direct;
        let rows_per_request = if direct {
            m.batch_infer
        } else {
            cfg.rows_per_request(m.batch_infer)
        };
        let latency = LatencyModel::new(device, m, cfg.slo_s());
        // never coalesce past the point where the oldest request's SLO
        // deadline could still be met after one execute
        let batcher = AdaptiveBatcher::new(m.batch_infer, cfg.batch_window_s, m.d)
            .with_deadline_slack(latency.exec_s());
        ServeEngine {
            batching,
            rows_per_request,
            slo_s: cfg.slo_s(),
            batcher,
            queue: RequestQueue::new(),
            latency,
            scheduler: Scheduler::new(cfg.defer_backlog, cfg.max_defers),
            serving: ServingCache::new(m.classes),
            disable_serving_cache,
            scratch: Vec::new(),
            executes: 0,
            served: 0,
        }
    }

    /// Rows the simulation must draw per inference request.
    pub fn rows_per_request(&self) -> usize {
        self.rows_per_request
    }

    /// Latency deadline for a request arriving at `t`.
    pub fn deadline(&self, t: f64) -> f64 {
        t + self.slo_s
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_queue_depth(&self) -> usize {
        self.queue.peak_depth()
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    pub fn scheduler_mut(&mut self) -> &mut Scheduler {
        &mut self.scheduler
    }

    pub fn latency_summary(&self) -> LatencySummary {
        self.latency.summary()
    }

    pub fn serving_rebuilds(&self) -> u64 {
        self.serving.rebuilds
    }

    pub fn serving_hits(&self) -> u64 {
        self.serving.hits
    }

    /// Padded artifact executions performed so far.
    pub fn executes(&self) -> u64 {
        self.executes
    }

    /// Mean requests per execute: 1.0 when batching never engaged,
    /// including request-free runs (matches the `Report` field contract).
    pub fn avg_batch_requests(&self) -> f64 {
        if self.executes == 0 {
            1.0
        } else {
            self.served as f64 / self.executes as f64
        }
    }

    /// Flush every batch whose window expired by `now` (called before each
    /// event so service order follows virtual time).
    pub fn pump(
        &mut self,
        now: f64,
        sess: &ModelSession,
        params: &Params,
        cwr: &Cwr,
        scenarios: &[Scenario],
    ) -> Result<Vec<ServedRequest>> {
        let mut out = Vec::new();
        while self.batcher.due(&self.queue, now) {
            let due = self.batcher.due_t(&self.queue).unwrap();
            let batch = self.batcher.take_batch(&mut self.queue);
            out.extend(self.serve_batch(batch, due, sess, params, cwr, scenarios)?);
        }
        Ok(out)
    }

    /// Accept one arriving request; returns any requests served as a
    /// consequence (immediately in direct/window-0 mode, on capacity or
    /// scenario boundaries otherwise).
    pub fn submit(
        &mut self,
        req: QueuedRequest,
        sess: &ModelSession,
        params: &Params,
        cwr: &Cwr,
        scenarios: &[Scenario],
    ) -> Result<Vec<ServedRequest>> {
        let arrival_t = req.arrival_t;
        if !self.batching {
            return self.serve_batch(vec![req], arrival_t, sess, params, cwr, scenarios);
        }
        let mut out = Vec::new();
        if self.batcher.must_flush_before(&self.queue, req.scenario, req.rows) {
            let batch = self.batcher.take_batch(&mut self.queue);
            out.extend(self.serve_batch(batch, arrival_t, sess, params, cwr, scenarios)?);
        }
        self.queue.push(req);
        if self.queue.rows_pending() >= self.batcher.capacity_rows {
            let batch = self.batcher.take_batch(&mut self.queue);
            out.extend(self.serve_batch(batch, arrival_t, sess, params, cwr, scenarios)?);
        }
        Ok(out)
    }

    /// Serve everything still queued at `now` (end of stream, or a
    /// fine-tuning round is about to occupy the device).
    pub fn drain(
        &mut self,
        now: f64,
        sess: &ModelSession,
        params: &Params,
        cwr: &Cwr,
        scenarios: &[Scenario],
    ) -> Result<Vec<ServedRequest>> {
        let mut out = Vec::new();
        while !self.queue.is_empty() {
            let batch = self.batcher.take_batch(&mut self.queue);
            out.extend(self.serve_batch(batch, now, sess, params, cwr, scenarios)?);
        }
        Ok(out)
    }

    /// Execute one batch due at `due`: ensure the bank-installed serving θ,
    /// pack + pad, run the artifact once, scatter predictions and energy
    /// scores back per request, and charge latency.
    fn serve_batch(
        &mut self,
        batch: Vec<QueuedRequest>,
        due: f64,
        sess: &ModelSession,
        params: &Params,
        cwr: &Cwr,
        scenarios: &[Scenario],
    ) -> Result<Vec<ServedRequest>> {
        if batch.is_empty() {
            return Ok(Vec::new());
        }
        let scenario = batch[0].scenario;
        debug_assert!(batch.iter().all(|r| r.scenario == scenario));
        self.ensure_serving(scenario, sess, params, cwr, scenarios)?;
        let packed = self.batcher.pack_into(&batch, &mut self.scratch);
        let serving = self.serving.params.as_ref().unwrap();
        // ONE artifact execution serves every coalesced request's
        // prediction and OOD energy score.
        let logits = sess.infer(serving, &packed.x)?;
        self.scratch = packed.x;
        let pred = logits.argmax_rows();
        let lse = logits.logsumexp_rows();

        let exec_s = self.latency.exec_s();
        let service_start = self.scheduler.admit_serve(due, exec_s);
        self.latency.charge_execute(exec_s);
        self.executes += 1;
        let queue_depth = self.queue.len();
        let batch_requests = batch.len();
        let mut out = Vec::with_capacity(batch_requests);
        for (req, span) in batch.iter().zip(&packed.spans) {
            let rows = span.row0..span.row0 + span.rows;
            let correct = pred[rows.clone()]
                .iter()
                .zip(&req.y)
                .filter(|(p, t)| **p == **t as usize)
                .count();
            let acc = correct as f32 / req.y.len() as f32;
            let row_lse = &lse[rows];
            let score = row_lse.iter().map(|&s| -s as f64).sum::<f64>()
                / row_lse.len() as f64;
            let latency_s =
                self.latency.observe(service_start - req.arrival_t, exec_s);
            if debug_enabled() {
                let (t, scenario, acc, mean_score) =
                    (req.arrival_t, req.scenario, acc, score);
                eprintln!(
                    "[dbg] t={t:.0} scen={scenario} acc={acc:.3} energy={mean_score:.3}"
                );
            }
            self.served += 1;
            out.push(ServedRequest {
                arrival_t: req.arrival_t,
                scenario: req.scenario,
                accuracy: acc,
                energy_score: score,
                stale_batches: req.stale_batches,
                latency_s,
                batch_requests,
                queue_depth,
            });
        }
        Ok(out)
    }

    /// Serve with the consolidated head for past classes, keeping the live
    /// training rows for classes of the current scenario.  The
    /// bank-installed θ is cached: flushes between parameter/bank changes
    /// reuse it with zero copies.
    ///
    /// Every rebuild ends with [`ModelSession::warm_infer`], which
    /// marshals the serving θ *and* pre-builds the backend's packed
    /// forward panels for it — packs install together with the CWR bank,
    /// so steady-state request serving never marshals and never packs.
    fn ensure_serving(
        &mut self,
        scenario: usize,
        sess: &ModelSession,
        params: &Params,
        cwr: &Cwr,
        scenarios: &[Scenario],
    ) -> Result<()> {
        let cache_ok = !self.disable_serving_cache
            && self.serving.is_valid(params, cwr, scenario);
        if cache_ok {
            self.serving.hits += 1;
            return Ok(());
        }
        self.serving.rebuilds += 1;
        if self.serving.params.is_none() {
            // first request: allocate the slot (keeps its id for good)
            self.serving.params = Some(params.clone());
        } else {
            self.serving.params.as_mut().unwrap().copy_from(params);
        }
        self.serving.except.assign(&scenarios[scenario].classes);
        let p = self.serving.params.as_mut().unwrap();
        cwr.install_except(&sess.m, p, &self.serving.except);
        self.serving.src_id = params.id();
        self.serving.src_gen = params.generation();
        self.serving.cwr_gen = cwr.generation();
        self.serving.scenario = scenario;
        sess.warm_infer(self.serving.params.as_ref().unwrap())
    }
}
