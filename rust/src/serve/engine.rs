//! The event-driven serving control plane.
//!
//! The seed engine was simulation-shaped: `submit`/`pump`/`drain` returned
//! flat `Vec<ServedRequest>`s, admission was implicit (everything entered
//! an unbounded FIFO), and one private single-slot `ServingCache` meant
//! every scenario change rebuilt the serving θ.  PR 5 redesigns the public
//! API around two verbs:
//!
//! * [`ServeEngine::on_arrival`]`(req) -> `[`Admission`] — the admission
//!   decision at the arrival instant: `Accepted` (queued) or
//!   `Dropped{reason}` under the shedding policy (`--max-queue` depth cap,
//!   optional SLO-infeasibility test);
//! * [`ServeEngine::poll`]`(now, ctx) -> Vec<`[`ServeEvent`]`>` — advance
//!   virtual time: flush every batch that is due or at capacity and
//!   report what happened (`RequestServed`, `RequestDropped`,
//!   `BatchExecuted`, `BankInstalled`).  [`ServeEngine::drain`] is the
//!   same loop unconditioned on due times (end of stream, or a
//!   fine-tuning round is about to occupy the device).
//!
//! Queue order comes from the [`AdmissionPolicy`] (`--queue-policy
//! fifo|edf`); serving θ comes from the [`BankSet`] — one resident
//! bank-installed θ per active scenario — so the batcher composes
//! *mixed-scenario* batches and the engine groups them by scenario at
//! execute time, scattering per-request predictions through the right
//! head with zero rebuilds once the banks are warm.
//!
//! The engine stays backend-agnostic: every execute goes through the
//! session's [`crate::runtime::Backend`], so the same control plane runs
//! on PJRT artifacts and the pure-Rust reference executor
//! (`tests/serving_engine.rs` drives it against a *really executing*
//! backend in CI).
//!
//! Three operating modes, all seed-deterministic:
//!
//! * **direct** (`--no-batching`): full `batch_infer`-row draws; every
//!   request fills an execute, so each poll after an arrival serves it
//!   immediately — structurally the pre-engine request path;
//! * **window 0** (the default): same row economics through the queue +
//!   batcher; with FIFO and no shedding, reports are bit-identical to the
//!   direct path (and to the pre-redesign engine);
//! * **window > 0**: requests draw fewer rows, wait up to the
//!   virtual-time window, and share padded executes per scenario group;
//!   per-request latency = queueing delay + batched service time.
//!
//! # Fault tolerance
//!
//! Since PR 6 every batch execute runs under the [`super::recovery`]
//! machinery: failed executes retry with exponential virtual-time backoff;
//! a streak of batch failures trips a circuit breaker, and while it is
//! open the engine serves from the stale resident bank (requests marked
//! `degraded`) or sheds with `Dropped{backend-unavailable}`; a mid-flush
//! failure requeues the unserved groups in order, so no request is ever
//! lost across retry/requeue/degrade.  With `recovery.enabled == false`
//! the first error propagates out of [`ServeEngine::poll`] unchanged.

// Serving hot path: every failure must surface as a recoverable Result
// (reachable under injected faults), never a panic.
#![deny(clippy::disallowed_methods)]

use anyhow::Result;

use crate::cost::device::DeviceModel;
use crate::data::benchmarks::Scenario;
use crate::metrics::hist::{HistRegistry, Histogram};
use crate::model::{Cwr, ModelSession, Params};
use crate::runtime::artifact::ModelManifest;
use crate::trace::{Lane, Tracer};

use super::admission::{Admission, AdmissionPolicy, DropReason, ShedPolicy};
use super::banks::{BankInstall, BankSet};
use super::batcher::AdaptiveBatcher;
use super::latency::{LatencyModel, LatencySummary};
use super::queue::{QueuedRequest, RequestQueue};
use super::recovery::{BreakerState, CircuitBreaker, RecoveryConfig};
use super::scheduler::Scheduler;
use super::ServeConfig;

/// Trace instant name for a drop reason (`&'static` for the event store).
fn drop_name(reason: DropReason) -> &'static str {
    match reason {
        DropReason::QueueFull => "drop_queue_full",
        DropReason::SloInfeasible => "drop_slo_infeasible",
        DropReason::BackendUnavailable => "drop_backend_unavailable",
    }
}

/// Everything the control plane needs to execute a batch, borrowed from
/// the simulation for the duration of one `poll`/`drain` call.  Bundling
/// the borrows keeps the public API two-argument and lets library users
/// drive the engine without a [`crate::sim::Simulation`].
pub struct ServeCtx<'a, 'b> {
    pub sess: &'a ModelSession<'b>,
    /// The live (training) parameters banks are built from.
    pub params: &'a Params,
    pub cwr: &'a Cwr,
    pub scenarios: &'a [Scenario],
}

/// One completed request, in service order.
#[derive(Clone, Copy, Debug)]
pub struct ServedRequest {
    pub arrival_t: f64,
    pub scenario: usize,
    pub accuracy: f32,
    /// Mean energy score `-logsumexp` over the request's rows (feeds the
    /// scenario-change detector in service order).
    pub energy_score: f64,
    pub stale_batches: usize,
    /// End-to-end latency: queueing delay + batched service time.
    pub latency_s: f64,
    /// Requests sharing this request's execute (1 = unbatched).
    pub batch_requests: usize,
    /// Requests still waiting when this one was served: queued, plus
    /// flush-mates in later scenario groups of the same mixed flush.
    pub queue_depth: usize,
    /// Completion passed the request's own `deadline_t`.
    pub deadline_miss: bool,
    /// Served from a *stale* resident bank while the circuit breaker was
    /// open (fingerprint-excluded, like the latency fields).
    pub degraded: bool,
}

/// What a [`ServeEngine::poll`]/[`ServeEngine::drain`] call observed.
#[derive(Clone, Debug)]
pub enum ServeEvent {
    /// A request completed (the only event the simulation consumes —
    /// accuracies and energy scores flow to the report and the
    /// scenario-change detector in service order).
    RequestServed(ServedRequest),
    /// A request was shed at arrival; reported by the next poll so the
    /// event stream is complete.
    RequestDropped {
        arrival_t: f64,
        scenario: usize,
        deadline_t: f64,
        reason: DropReason,
    },
    /// One padded artifact execution ran at `t` for `requests` requests
    /// (`rows` real rows) of `scenario`.
    BatchExecuted { t: f64, scenario: usize, requests: usize, rows: usize },
    /// A scenario's serving θ was (re)built and warm-packed; `evicted`
    /// names the scenario whose bank was LRU-evicted, if any.
    BankInstalled { scenario: usize, evicted: Option<usize> },
}

/// Serving control-plane state (one per simulation).
pub struct ServeEngine {
    rows_per_request: usize,
    slo_s: f64,
    batcher: AdaptiveBatcher,
    queue: RequestQueue,
    policy: Box<dyn AdmissionPolicy>,
    shed: ShedPolicy,
    latency: LatencyModel,
    scheduler: Scheduler,
    banks: BankSet,
    disable_serving_cache: bool,
    scratch: Vec<f32>,
    /// Events recorded between polls (drops at arrival time).
    pending: Vec<ServeEvent>,
    executes: u64,
    served: u64,
    drops_queue_full: u64,
    drops_slo_infeasible: u64,
    recovery: RecoveryConfig,
    breaker: CircuitBreaker,
    serve_retries: u64,
    flush_failures: u64,
    degraded_serves: u64,
    drops_backend_unavailable: u64,
    /// Virtual-time event recorder ([`Tracer::disabled`] by default:
    /// zero allocations, one inlined check per record site).
    tracer: Tracer,
    /// Queue depth sampled at each accepted arrival.
    queue_hist: Histogram,
    /// Real rows per padded execute.
    batch_rows_hist: Histogram,
}

impl ServeEngine {
    pub fn new(
        m: &ModelManifest,
        device: &DeviceModel,
        cfg: &ServeConfig,
        direct: bool,
        disable_serving_cache: bool,
    ) -> ServeEngine {
        // `direct` forces the degenerate economics: full-draw requests
        // with a zero window fill and flush their own execute at the
        // arrival instant — bit-identical to the pre-engine request path,
        // but exercising the real admission/pack/scatter machinery.
        let (rows_per_request, window_s) = if direct {
            (m.batch_infer, 0.0)
        } else {
            (cfg.rows_per_request(m.batch_infer), cfg.batch_window_s)
        };
        let latency = LatencyModel::new(device, m, cfg.slo_s());
        // never coalesce past the point where the policy-next request's
        // SLO deadline could still be met after one execute
        let batcher = AdaptiveBatcher::new(m.batch_infer, window_s, m.d)
            .with_deadline_slack(latency.exec_s());
        ServeEngine {
            rows_per_request,
            slo_s: cfg.slo_s(),
            batcher,
            queue: RequestQueue::new(),
            policy: cfg.queue_policy.build(),
            shed: ShedPolicy {
                max_queue: cfg.max_queue,
                shed_infeasible: cfg.shed_infeasible,
            },
            latency,
            scheduler: Scheduler::new(cfg.defer_backlog, cfg.max_defers),
            banks: BankSet::new(m.classes, cfg.bank_capacity),
            disable_serving_cache,
            scratch: Vec::new(),
            pending: Vec::new(),
            executes: 0,
            served: 0,
            drops_queue_full: 0,
            drops_slo_infeasible: 0,
            recovery: cfg.recovery,
            breaker: cfg.recovery.breaker(),
            serve_retries: 0,
            flush_failures: 0,
            degraded_serves: 0,
            drops_backend_unavailable: 0,
            tracer: Tracer::disabled(),
            queue_hist: Histogram::new(),
            batch_rows_hist: Histogram::new(),
        }
    }

    /// Attach a tracer (shared with the simulation / backend decorator).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        self.tracer = tracer;
    }

    pub fn tracer(&self) -> &Tracer {
        &self.tracer
    }

    /// Copy the engine's distributions into a report registry: end-to-end
    /// latency (overall and per scenario), queue depth at arrival, and
    /// real rows per execute.
    pub fn fill_hists(&self, reg: &mut HistRegistry) {
        reg.insert("serve/latency_ms", self.latency.hist().scaled(1e3));
        for (scenario, h) in self.latency.scenario_hists() {
            reg.insert(&format!("serve/latency_ms/s{scenario}"), h.scaled(1e3));
        }
        reg.insert("serve/queue_depth", self.queue_hist.clone());
        reg.insert("serve/batch_rows", self.batch_rows_hist.clone());
    }

    /// Rows the caller must draw per inference request.
    pub fn rows_per_request(&self) -> usize {
        self.rows_per_request
    }

    /// Latency deadline for a request arriving at `t` under the SLO.
    pub fn deadline(&self, t: f64) -> f64 {
        t + self.slo_s
    }

    pub fn queue_depth(&self) -> usize {
        self.queue.len()
    }

    pub fn peak_queue_depth(&self) -> usize {
        self.queue.peak_depth()
    }

    pub fn scheduler(&self) -> &Scheduler {
        &self.scheduler
    }

    pub fn scheduler_mut(&mut self) -> &mut Scheduler {
        &mut self.scheduler
    }

    pub fn latency_summary(&self) -> LatencySummary {
        self.latency.summary()
    }

    /// Per-scenario latency digests (ascending scenario order).
    pub fn per_scenario_latency(&self) -> Vec<crate::metrics::ScenarioLatency> {
        self.latency.per_scenario()
    }

    /// Served requests whose completion passed their own deadline.
    pub fn deadline_misses(&self) -> u64 {
        self.latency.deadline_misses()
    }

    /// Bank (re)builds — the old single-slot cache's "rebuilds" counter,
    /// now summed over every resident bank.
    pub fn serving_rebuilds(&self) -> u64 {
        self.banks.rebuilds()
    }

    /// Ensures served by a resident, current bank.
    pub fn serving_hits(&self) -> u64 {
        self.banks.hits()
    }

    pub fn bank_evictions(&self) -> u64 {
        self.banks.evictions()
    }

    pub fn banks_resident(&self) -> usize {
        self.banks.resident()
    }

    pub fn banks_peak_resident(&self) -> usize {
        self.banks.peak_resident()
    }

    /// The ordering policy's name (`"fifo"` / `"edf"`).
    pub fn queue_policy_name(&self) -> &'static str {
        self.policy.name()
    }

    pub fn drops_queue_full(&self) -> u64 {
        self.drops_queue_full
    }

    pub fn drops_slo_infeasible(&self) -> u64 {
        self.drops_slo_infeasible
    }

    /// Requests shed at serve time because the circuit breaker was open
    /// and no stale resident bank could stand in.
    pub fn drops_backend_unavailable(&self) -> u64 {
        self.drops_backend_unavailable
    }

    /// Requests shed, all reasons (arrival- and serve-time).
    pub fn requests_dropped(&self) -> u64 {
        self.drops_queue_full
            + self.drops_slo_infeasible
            + self.drops_backend_unavailable
    }

    /// Batch execute retries performed (attempts beyond the first).
    pub fn serve_retries(&self) -> u64 {
        self.serve_retries
    }

    /// Flushes whose batch exhausted its retries (the group was requeued
    /// and the error absorbed by the recovery layer).
    pub fn flush_failures(&self) -> u64 {
        self.flush_failures
    }

    /// Times the circuit breaker tripped open.
    pub fn breaker_trips(&self) -> u64 {
        self.breaker.trips()
    }

    /// Requests served from a stale resident bank while the breaker was
    /// open.
    pub fn degraded_serves(&self) -> u64 {
        self.degraded_serves
    }

    /// Current circuit-breaker state.
    pub fn breaker_state(&self) -> BreakerState {
        self.breaker.state()
    }

    /// Padded artifact executions performed so far.
    pub fn executes(&self) -> u64 {
        self.executes
    }

    /// Requests served so far (the fleet layer aggregates this across
    /// engines to recompute the mean batch occupancy).
    pub fn served(&self) -> u64 {
        self.served
    }

    /// The serving-side latency ledger (read-only).  The fleet layer
    /// merges these across engines in engine-id order so fleet
    /// percentiles are nearest-rank over the union of exact samples.
    pub fn latency_model(&self) -> &LatencyModel {
        &self.latency
    }

    /// Proactively install `scenario`'s serving bank at virtual time `t`
    /// (the fleet router's rebalancing path: a hot scenario gets a second
    /// resident bank so later affinity routes land warm).  Exactly the
    /// ensure path a serve would take; an install is reported through the
    /// next poll's events like any other [`ServeEvent::BankInstalled`].
    pub fn warm_bank(
        &mut self,
        scenario: usize,
        t: f64,
        ctx: &ServeCtx,
    ) -> Result<()> {
        match self.banks.ensure(scenario, ctx, self.disable_serving_cache)? {
            BankInstall::Hit => {}
            BankInstall::Installed { evicted } => {
                self.tracer.instant(
                    Lane::Engine,
                    "bank_install",
                    t,
                    &[
                        ("scenario", scenario as f64),
                        ("evicted", evicted.map(|s| s as f64).unwrap_or(-1.0)),
                    ],
                );
                self.pending.push(ServeEvent::BankInstalled { scenario, evicted });
            }
        }
        Ok(())
    }

    /// Mean requests per execute: 1.0 when batching never engaged,
    /// including request-free runs (matches the `Report` field contract).
    pub fn avg_batch_requests(&self) -> f64 {
        if self.executes == 0 {
            1.0
        } else {
            self.served as f64 / self.executes as f64
        }
    }

    /// Checkpoint the control plane at a quiesce point (queue drained, no
    /// pending events — every round boundary, by construction).  Persists
    /// the scheduler horizon, bank residency + counters, breaker state,
    /// the latency ledger, the queue's depth instrumentation, the engine
    /// counters, and the two engine-side histograms.  Serving θ banks are
    /// *not* serialized — [`ServeEngine::ckpt_load`] re-warms them from
    /// the live restored `(Params, Cwr)` through the normal ensure path.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        debug_assert!(
            self.queue.is_empty() && self.pending.is_empty(),
            "checkpointing a non-quiesced engine"
        );
        self.scheduler.ckpt_save(w);
        self.banks.ckpt_save(w);
        self.breaker.ckpt_save(w);
        self.latency.ckpt_save(w);
        self.queue.ckpt_save(w);
        w.u64(self.executes);
        w.u64(self.served);
        w.u64(self.drops_queue_full);
        w.u64(self.drops_slo_infeasible);
        w.u64(self.serve_retries);
        w.u64(self.flush_failures);
        w.u64(self.degraded_serves);
        w.u64(self.drops_backend_unavailable);
        w.f64s(self.queue_hist.samples());
        w.f64s(self.batch_rows_hist.samples());
    }

    /// Restore state saved by [`ServeEngine::ckpt_save`] into a freshly
    /// built engine (same config).  `ctx` carries the already-restored
    /// training θ the banks re-warm from.
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        ctx: &ServeCtx,
    ) -> Result<()> {
        self.scheduler.ckpt_load(r)?;
        self.banks.ckpt_load(r, ctx)?;
        self.breaker.ckpt_load(r)?;
        self.latency.ckpt_load(r)?;
        self.queue.ckpt_load(r)?;
        self.executes = r.u64()?;
        self.served = r.u64()?;
        self.drops_queue_full = r.u64()?;
        self.drops_slo_infeasible = r.u64()?;
        self.serve_retries = r.u64()?;
        self.flush_failures = r.u64()?;
        self.degraded_serves = r.u64()?;
        self.drops_backend_unavailable = r.u64()?;
        self.queue_hist = Histogram::new();
        for v in r.f64s()? {
            self.queue_hist.record(v);
        }
        self.batch_rows_hist = Histogram::new();
        for v in r.f64s()? {
            self.batch_rows_hist.record(v);
        }
        Ok(())
    }

    /// The verdict [`ServeEngine::on_arrival`] would return for `req`
    /// *right now*, without recording anything — the fleet router probes
    /// an affinity target with this so a `Dropped{queue-full}` hint can
    /// redirect the request to another engine before the drop is real.
    /// Pure: admission policies are stateless and the queue is untouched,
    /// so a matching `on_arrival` immediately after returns the same
    /// verdict.
    pub fn would_admit(&self, req: &QueuedRequest) -> Admission {
        let earliest_done = self
            .scheduler
            .earliest_completion(req.arrival_t, self.latency.exec_s());
        self.policy.admit(req, self.queue.len(), &self.shed, earliest_done)
    }

    /// Admission decision for one arriving request.  Accepted requests
    /// enter the queue (their test rows are already drawn — sampling at
    /// arrival keeps the world RNG stream in event order); dropped
    /// requests never execute, and the drop is reported by the next
    /// [`ServeEngine::poll`] as a [`ServeEvent::RequestDropped`].
    pub fn on_arrival(&mut self, req: QueuedRequest) -> Admission {
        let earliest_done = self
            .scheduler
            .earliest_completion(req.arrival_t, self.latency.exec_s());
        let verdict =
            self.policy.admit(&req, self.queue.len(), &self.shed, earliest_done);
        match verdict {
            Admission::Accepted => {
                let (t, scenario) = (req.arrival_t, req.scenario);
                self.queue.push(req);
                let depth = self.queue.len();
                self.queue_hist.record(depth as f64);
                self.tracer.instant(
                    Lane::Engine,
                    "arrival",
                    t,
                    &[("scenario", scenario as f64)],
                );
                self.tracer.counter(Lane::Engine, "queue_depth", t, depth as f64);
            }
            Admission::Dropped { reason } => {
                match reason {
                    DropReason::QueueFull => self.drops_queue_full += 1,
                    DropReason::SloInfeasible => self.drops_slo_infeasible += 1,
                    // never produced at arrival time (serve-time verdict),
                    // but account it if a custom policy ever returns it.
                    DropReason::BackendUnavailable => {
                        self.drops_backend_unavailable += 1
                    }
                }
                self.tracer.debug(
                    Lane::Engine,
                    drop_name(reason),
                    req.arrival_t,
                    &[("scenario", req.scenario as f64)],
                    format_args!(
                        "[dbg] t={:.0} scen={} DROP {}",
                        req.arrival_t,
                        req.scenario,
                        reason.name()
                    ),
                );
                self.pending.push(ServeEvent::RequestDropped {
                    arrival_t: req.arrival_t,
                    scenario: req.scenario,
                    deadline_t: req.deadline_t,
                    reason,
                });
            }
        }
        verdict
    }

    /// Advance virtual time to `now`: flush every batch whose window (or
    /// SLO slack) expired, and every full batch, in policy order.  Call
    /// before consuming each event-stream entry and after each arrival so
    /// service order follows virtual time.
    pub fn poll(&mut self, now: f64, ctx: &ServeCtx) -> Result<Vec<ServeEvent>> {
        self.tracer.set_now(now);
        let mut out = std::mem::take(&mut self.pending);
        let result = self.poll_inner(now, ctx, &mut out);
        self.finish_events(out, result)
    }

    fn poll_inner(
        &mut self,
        now: f64,
        ctx: &ServeCtx,
        out: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        loop {
            let due_t = self.batcher.due_t(&self.queue);
            let t = match due_t {
                Some(d) if d <= now => d,
                _ if self.batcher.capacity_reached(self.queue.rows_pending()) => now,
                _ => return Ok(()),
            };
            let batch = self.batcher.take_batch(&mut self.queue, self.policy.as_ref());
            if batch.is_empty() {
                return Ok(());
            }
            self.flush_absorbing(batch, t, ctx, out)?;
        }
    }

    /// Run one flush, absorbing the failure when recovery is enabled: the
    /// failing groups were requeued in order by `serve_flush`, the breaker
    /// recorded the failure, and the caller's loop makes progress — each
    /// iteration either serves (queue shrinks) or adds a breaker failure,
    /// and an open breaker degrades/sheds, so the loop terminates.  With
    /// recovery disabled the error propagates exactly as before PR 6.
    fn flush_absorbing(
        &mut self,
        batch: Vec<QueuedRequest>,
        t: f64,
        ctx: &ServeCtx,
        out: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        match self.serve_flush(batch, t, ctx, out) {
            Ok(()) => Ok(()),
            Err(e) if self.recovery.enabled => {
                self.flush_failures += 1;
                self.tracer.debug(
                    Lane::Engine,
                    "flush_failed",
                    t,
                    &[("absorbed", 1.0)],
                    format_args!("[dbg] t={t:.0} flush failed (absorbed): {e:#}"),
                );
                Ok(())
            }
            Err(e) => Err(e),
        }
    }

    /// Serve everything still queued at `now` regardless of windows (end
    /// of stream, or a fine-tuning round is about to occupy the device).
    pub fn drain(&mut self, now: f64, ctx: &ServeCtx) -> Result<Vec<ServeEvent>> {
        self.tracer.set_now(now);
        let mut out = std::mem::take(&mut self.pending);
        let result = (|| -> Result<()> {
            while !self.queue.is_empty() {
                let batch =
                    self.batcher.take_batch(&mut self.queue, self.policy.as_ref());
                if batch.is_empty() {
                    // a custom policy may decline to pick (next_index
                    // None on a non-empty queue): stop rather than spin
                    return Ok(());
                }
                self.flush_absorbing(batch, now, ctx, &mut out)?;
            }
            Ok(())
        })();
        self.finish_events(out, result)
    }

    /// On success hand the events to the caller; on failure re-stash them
    /// so the stream stays complete — their side effects (latency charges,
    /// served/executed counters) already persist in engine state, and a
    /// mid-flush backend error must not silently swallow the events of
    /// groups that did serve (or buffered drops) before it.
    fn finish_events(
        &mut self,
        out: Vec<ServeEvent>,
        result: Result<()>,
    ) -> Result<Vec<ServeEvent>> {
        match result {
            Ok(()) => Ok(out),
            Err(e) => {
                self.pending = out;
                Err(e)
            }
        }
    }

    /// Execute one flushed batch due at `due`: group by scenario (first
    /// appearance order — the service order within the flush) and run one
    /// padded execute per group against that scenario's resident bank θ.
    fn serve_flush(
        &mut self,
        batch: Vec<QueuedRequest>,
        due: f64,
        ctx: &ServeCtx,
        out: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        let mut groups: Vec<(usize, Vec<QueuedRequest>)> = Vec::new();
        for req in batch {
            match groups.iter_mut().find(|(s, _)| *s == req.scenario) {
                Some((_, g)) => g.push(req),
                None => groups.push((req.scenario, vec![req])),
            }
        }
        // flush-mates in later scenario groups were popped from the queue
        // but serve strictly after this group's execute — count them as
        // still waiting so `queue_depth` keeps its pre-PR5 meaning
        // (requests pending when this one was served).
        let mut waiting: usize = groups.iter().map(|(_, g)| g.len()).sum();
        let (flush_requests, flush_groups) = (waiting, groups.len());
        self.tracer.begin(Lane::Engine, "flush", due);
        let mut idx = 0;
        while idx < groups.len() {
            let (scenario, group) = &groups[idx];
            waiting -= group.len();
            // A standalone caller may poll() long after arrivals, so a
            // window-due flush time can predate batch members that
            // arrived after the anchor's window opened; service cannot
            // start before a request exists.  Clamp per scenario group
            // so a late arrival in one group never inflates another
            // group's service start.  (The simulator polls at every
            // arrival, so there this is a no-op and flush times are
            // unchanged.)
            let t = group.iter().fold(due, |d, r| d.max(r.arrival_t));
            if let Err(e) =
                self.serve_group_recovered(*scenario, group, t, waiting, ctx, out)
            {
                // serve_group is all-or-nothing (the fallible execute
                // precedes every per-request record), so the failing and
                // later groups are entirely unserved: put them back so a
                // recovering caller can retry — no request is ever lost.
                let unserved: Vec<QueuedRequest> =
                    groups.drain(idx..).flat_map(|(_, g)| g).collect();
                self.queue.requeue_front(unserved);
                self.tracer.end(
                    Lane::Engine,
                    t,
                    &[
                        ("groups", flush_groups as f64),
                        ("requests", flush_requests as f64),
                        ("err", 1.0),
                    ],
                );
                return Err(e);
            }
            idx += 1;
        }
        self.tracer.end(
            Lane::Engine,
            self.scheduler.device_free_at().max(due),
            &[
                ("groups", flush_groups as f64),
                ("requests", flush_requests as f64),
            ],
        );
        Ok(())
    }

    /// [`ServeEngine::serve_group`] under the recovery policy: consult the
    /// circuit breaker, retry with exponential virtual-time backoff, and
    /// record the outcome.  A half-open probe gets exactly one attempt.
    /// With recovery disabled this is a plain `serve_group` call.
    fn serve_group_recovered(
        &mut self,
        scenario: usize,
        group: &[QueuedRequest],
        due: f64,
        flush_waiting: usize,
        ctx: &ServeCtx,
        out: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        if !self.recovery.enabled {
            return self
                .serve_group(scenario, group, due, flush_waiting, ctx, out, false);
        }
        if !self.breaker.allow(due) {
            self.tracer.instant(
                Lane::Engine,
                "breaker_open",
                due,
                &[("scenario", scenario as f64)],
            );
            return self
                .serve_degraded(scenario, group, due, flush_waiting, ctx, out);
        }
        let retry = self.recovery.retry();
        let max_attempts = if self.breaker.state() == BreakerState::HalfOpen {
            1 // the probe: one attempt decides close vs reopen
        } else {
            retry.max_attempts
        };
        let mut attempt = 0u32;
        loop {
            attempt += 1;
            // failed attempts push the batch's due time back by the
            // cumulative backoff — charged through the virtual clock via
            // `Scheduler::admit_serve`, never wall time.
            let t = due + retry.total_backoff_s(attempt - 1);
            match self
                .serve_group(scenario, group, t, flush_waiting, ctx, out, false)
            {
                Ok(()) => {
                    self.serve_retries += (attempt - 1) as u64;
                    self.breaker.on_success();
                    return Ok(());
                }
                Err(e) if attempt >= max_attempts => {
                    self.serve_retries += (attempt - 1) as u64;
                    let trips0 = self.breaker.trips();
                    self.breaker.on_failure(t);
                    if self.breaker.trips() > trips0 {
                        self.tracer.instant(
                            Lane::Engine,
                            "breaker_trip",
                            t,
                            &[("scenario", scenario as f64)],
                        );
                    }
                    return Err(e);
                }
                Err(_) => {
                    // retry after backoff
                    self.tracer.instant(
                        Lane::Engine,
                        "retry",
                        t,
                        &[
                            ("scenario", scenario as f64),
                            ("attempt", attempt as f64),
                        ],
                    );
                }
            }
        }
    }

    /// The breaker is open: serve from the *stale* resident bank (marked
    /// degraded) when allowed and possible, otherwise shed every request
    /// in the group with `Dropped{backend-unavailable}`.  Either way this
    /// returns `Ok` — the engine makes progress while degraded.
    fn serve_degraded(
        &mut self,
        scenario: usize,
        group: &[QueuedRequest],
        due: f64,
        flush_waiting: usize,
        ctx: &ServeCtx,
        out: &mut Vec<ServeEvent>,
    ) -> Result<()> {
        if self.recovery.degraded_serving
            && self.banks.resident_params(scenario).is_some()
        {
            // the stale bank may itself fault mid-execute; fall through
            // to shedding rather than failing the flush.
            match self
                .serve_group(scenario, group, due, flush_waiting, ctx, out, true)
            {
                Ok(()) => {
                    self.degraded_serves += group.len() as u64;
                    self.tracer.instant(
                        Lane::Engine,
                        "degraded_serve",
                        due,
                        &[
                            ("scenario", scenario as f64),
                            ("requests", group.len() as f64),
                        ],
                    );
                    return Ok(());
                }
                Err(e) => {
                    self.tracer.debug(
                        Lane::Engine,
                        "degraded_serve_failed",
                        due,
                        &[("scenario", scenario as f64)],
                        format_args!(
                            "[dbg] t={due:.0} scen={scenario} degraded serve \
                             failed, shedding: {e:#}"
                        ),
                    );
                }
            }
        }
        for req in group {
            self.drops_backend_unavailable += 1;
            self.tracer.instant(
                Lane::Engine,
                drop_name(DropReason::BackendUnavailable),
                due,
                &[("scenario", req.scenario as f64)],
            );
            out.push(ServeEvent::RequestDropped {
                arrival_t: req.arrival_t,
                scenario: req.scenario,
                deadline_t: req.deadline_t,
                reason: DropReason::BackendUnavailable,
            });
        }
        Ok(())
    }

    /// One padded execute for a same-scenario group: ensure the resident
    /// bank θ, pack + pad, run the artifact once, scatter predictions and
    /// energy scores back per request, and charge latency.  `degraded`
    /// skips the bank freshness check and serves from the stale resident
    /// bank (breaker-open path); the fallible calls all precede the first
    /// per-request record, so a failure leaves no partial state.
    #[allow(clippy::too_many_arguments)]
    fn serve_group(
        &mut self,
        scenario: usize,
        group: &[QueuedRequest],
        due: f64,
        flush_waiting: usize,
        ctx: &ServeCtx,
        out: &mut Vec<ServeEvent>,
        degraded: bool,
    ) -> Result<()> {
        // stamp the virtual clock so backend-boundary spans (the
        // `TracingBackend` decorator) land at this execute's due time.
        self.tracer.set_now(due);
        if !degraded {
            match self.banks.ensure(scenario, ctx, self.disable_serving_cache)? {
                BankInstall::Hit => {}
                BankInstall::Installed { evicted } => {
                    self.tracer.instant(
                        Lane::Engine,
                        "bank_install",
                        due,
                        &[
                            ("scenario", scenario as f64),
                            (
                                "evicted",
                                evicted.map(|s| s as f64).unwrap_or(-1.0),
                            ),
                        ],
                    );
                    out.push(ServeEvent::BankInstalled { scenario, evicted });
                }
            }
        }
        let params = if degraded {
            self.banks.resident_params(scenario).ok_or_else(|| {
                anyhow::anyhow!(
                    "no resident bank for scenario {scenario} to serve degraded"
                )
            })?
        } else {
            self.banks.params(scenario)?
        };
        let packed = self.batcher.pack_into(group, &mut self.scratch);
        // ONE artifact execution serves every coalesced request's
        // prediction and OOD energy score, through this scenario's head.
        let logits = ctx.sess.infer(params, &packed.x)?;
        self.scratch = packed.x;
        let pred = logits.argmax_rows();
        let lse = logits.logsumexp_rows();

        // injected latency spikes (fault harness) accrued on this execute
        // are charged as extra service time — virtual clock, never wall.
        let spike_s = ctx.sess.be.take_injected_delay_s();
        let exec_s = self.latency.exec_s() + spike_s;
        let service_start = self.scheduler.admit_serve(due, exec_s);
        self.latency.charge_execute(exec_s);
        self.executes += 1;
        self.batch_rows_hist.record(packed.rows_used as f64);
        self.tracer.span(
            Lane::Engine,
            "execute",
            service_start,
            service_start + exec_s,
            &[
                ("scenario", scenario as f64),
                ("requests", group.len() as f64),
                ("rows", packed.rows_used as f64),
                ("spike_s", spike_s),
                ("degraded", degraded as u64 as f64),
            ],
        );
        out.push(ServeEvent::BatchExecuted {
            t: service_start,
            scenario,
            requests: group.len(),
            rows: packed.rows_used,
        });
        let queue_depth = self.queue.len() + flush_waiting;
        let batch_requests = group.len();
        let completion = service_start + exec_s;
        for (req, span) in group.iter().zip(&packed.spans) {
            let rows = span.row0..span.row0 + span.rows;
            let correct = pred[rows.clone()]
                .iter()
                .zip(&req.y)
                .filter(|(p, t)| **p == **t as usize)
                .count();
            let acc = correct as f32 / req.y.len() as f32;
            let row_lse = &lse[rows];
            let score = row_lse.iter().map(|&s| -s as f64).sum::<f64>()
                / row_lse.len() as f64;
            let deadline_miss = completion > req.deadline_t;
            let latency_s = self.latency.observe(
                scenario,
                service_start - req.arrival_t,
                exec_s,
                deadline_miss,
            );
            self.tracer.debug(
                Lane::Engine,
                "served",
                req.arrival_t,
                &[
                    ("scenario", req.scenario as f64),
                    ("latency_s", latency_s),
                    ("miss", deadline_miss as u64 as f64),
                ],
                format_args!(
                    "[dbg] t={:.0} scen={} acc={acc:.3} energy={score:.3}",
                    req.arrival_t, req.scenario
                ),
            );
            self.served += 1;
            out.push(ServeEvent::RequestServed(ServedRequest {
                arrival_t: req.arrival_t,
                scenario: req.scenario,
                accuracy: acc,
                energy_score: score,
                stale_batches: req.stale_batches,
                latency_s,
                batch_requests,
                queue_depth,
                deadline_miss,
                degraded,
            }));
        }
        Ok(())
    }
}
