//! Multi-head serving-θ residency: one bank-installed serving parameter
//! vector per *active scenario*, kept warm across requests.
//!
//! The seed engine kept a single cached serving θ keyed on
//! `(params, cwr, scenario)` — correct, but every scenario change in a
//! mixed burst invalidated it, so interleaved traffic paid a full-θ copy,
//! a head install, a marshal, and a weight re-pack *per alternation*.
//! The [`BankSet`] shards that cache by scenario: each resident bank is a
//! [`Params`] holding the live θ with the consolidated CWR rows installed
//! for every seen class *except* the bank's own scenario
//! ([`crate::model::Cwr::build_serving`]), warm-packed at install time via
//! [`crate::model::ModelSession::warm_infer`] → `Backend::warm`, and
//! invalidated only by the live `(Params, Cwr)` generation counters — so a
//! scenario-interleaved burst runs entirely on resident banks with zero
//! rebuilds after warm-up.
//!
//! Residency is LRU-bounded (`--bank-capacity`, default 4): evicting a
//! bank releases its marshalled θ literal and packed panels through
//! [`crate::model::ModelSession::release_params`] → `Backend::release`,
//! so inactive scenarios stop holding backend memory.

// Serving hot path: every failure must surface as a recoverable Result
// (reachable under injected faults), never a panic.
#![deny(clippy::disallowed_methods)]

use anyhow::Result;

use crate::bitset::BitSet;
use crate::model::session::THETA_CACHE_CAP;
use crate::model::Params;

use super::engine::ServeCtx;

/// Hard ceiling on residency: banks plus the live θ and a couple of
/// policy-held references must fit the session's θ-value cache
/// ([`THETA_CACHE_CAP`]) with room to spare — if resident banks alone
/// could fill it, every overflow would drain the whole cache (live θ
/// included) while the banks' generation snapshots still read as valid,
/// so `ensure` would report hits whose literals and packs are gone.
pub const MAX_BANK_CAPACITY: usize = THETA_CACHE_CAP / 2;

/// Outcome of [`BankSet::ensure`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BankInstall {
    /// The scenario's bank was resident and current — zero copies.
    Hit,
    /// The bank was (re)built and warm-packed; `evicted` names the
    /// scenario whose bank was LRU-evicted to make room, if any.
    Installed { evicted: Option<usize> },
}

/// One resident serving θ.
struct Bank {
    scenario: usize,
    params: Params,
    /// Live-θ snapshot the bank was built from.
    src_id: u64,
    src_gen: u64,
    cwr_gen: u64,
    /// LRU tick of the last `ensure` that touched this bank.
    last_used: u64,
}

/// LRU-bounded map of scenario → resident bank-installed serving θ.
pub struct BankSet {
    banks: Vec<Bank>,
    capacity: usize,
    clock: u64,
    /// scratch: live-scenario classes excluded from the bank install.
    except: BitSet,
    rebuilds: u64,
    hits: u64,
    evictions: u64,
    peak_resident: usize,
}

impl BankSet {
    /// `classes` sizes the install-exclusion scratch; `capacity` bounds
    /// residency (clamped to `1..=`[`MAX_BANK_CAPACITY`]).
    pub fn new(classes: usize, capacity: usize) -> BankSet {
        BankSet {
            banks: Vec::new(),
            capacity: capacity.clamp(1, MAX_BANK_CAPACITY),
            clock: 0,
            except: BitSet::new(classes),
            rebuilds: 0,
            hits: 0,
            evictions: 0,
            peak_resident: 0,
        }
    }

    /// Make `scenario`'s bank resident and current.  A valid resident
    /// bank is a pure cache hit; otherwise the bank is rebuilt from the
    /// live θ (evicting the LRU bank when at capacity) and warm-packed.
    /// `force_rebuild` is the `--disable-serving-cache` debug knob:
    /// reports must be bit-identical either way.
    pub fn ensure(
        &mut self,
        scenario: usize,
        ctx: &ServeCtx,
        force_rebuild: bool,
    ) -> Result<BankInstall> {
        self.clock += 1;
        let clock = self.clock;
        if let Some(bank) = self.banks.iter_mut().find(|b| b.scenario == scenario) {
            bank.last_used = clock;
            let valid = !force_rebuild
                && bank.src_id == ctx.params.id()
                && bank.src_gen == ctx.params.generation()
                && bank.cwr_gen == ctx.cwr.generation();
            if valid {
                self.hits += 1;
                return Ok(BankInstall::Hit);
            }
            self.rebuilds += 1;
            Self::build(bank, scenario, ctx, &mut self.except)?;
            return Ok(BankInstall::Installed { evicted: None });
        }
        self.rebuilds += 1;
        if self.banks.len() >= self.capacity {
            // evict the least-recently-used bank and reuse its θ slot
            // (the Params id persists; its stale cached literal + packs
            // are released eagerly so the backend frees them now rather
            // than at the next generation collision).
            let idx = self
                .banks
                .iter()
                .enumerate()
                .min_by_key(|(_, b)| b.last_used)
                .map(|(i, _)| i)
                .ok_or_else(|| {
                    anyhow::anyhow!("bank set at capacity but empty")
                })?;
            let bank = &mut self.banks[idx];
            let evicted = bank.scenario;
            self.evictions += 1;
            ctx.sess.release_params(bank.params.id());
            bank.scenario = scenario;
            bank.last_used = clock;
            Self::build(bank, scenario, ctx, &mut self.except)?;
            return Ok(BankInstall::Installed { evicted: Some(evicted) });
        }
        let mut bank = Bank {
            scenario,
            params: ctx.params.clone(),
            src_id: 0,
            src_gen: 0,
            cwr_gen: 0,
            last_used: clock,
        };
        Self::build(&mut bank, scenario, ctx, &mut self.except)?;
        self.banks.push(bank);
        self.peak_resident = self.peak_resident.max(self.banks.len());
        Ok(BankInstall::Installed { evicted: None })
    }

    /// (Re)build `bank`'s serving θ from the live parameters and warm the
    /// backend (marshal + pre-pack), recording the generation snapshot.
    fn build(
        bank: &mut Bank,
        scenario: usize,
        ctx: &ServeCtx,
        except: &mut BitSet,
    ) -> Result<()> {
        except.assign(&ctx.scenarios[scenario].classes);
        ctx.cwr.build_serving(&ctx.sess.m, ctx.params, &mut bank.params, except);
        bank.src_id = ctx.params.id();
        bank.src_gen = ctx.params.generation();
        bank.cwr_gen = ctx.cwr.generation();
        ctx.sess.warm_infer(&bank.params)
    }

    /// The resident serving θ for `scenario` (must follow a successful
    /// [`BankSet::ensure`] for it — a missing bank is a recoverable
    /// engine-sequencing error, not a panic).
    pub fn params(&self, scenario: usize) -> Result<&Params> {
        self.resident_params(scenario).ok_or_else(|| {
            anyhow::anyhow!(
                "bank for scenario {scenario} not resident; call ensure first"
            )
        })
    }

    /// The resident bank for `scenario` if one exists, *without* checking
    /// freshness or rebuilding — the degraded-serving path uses this to
    /// serve from a stale bank while the circuit breaker is open.
    pub fn resident_params(&self, scenario: usize) -> Option<&Params> {
        self.banks
            .iter()
            .find(|b| b.scenario == scenario)
            .map(|b| &b.params)
    }

    /// Banks (re)built: every miss, invalidation, or forced rebuild.
    pub fn rebuilds(&self) -> u64 {
        self.rebuilds
    }

    /// Ensures served by a resident, current bank (zero copies).
    pub fn hits(&self) -> u64 {
        self.hits
    }

    /// Banks LRU-evicted to respect the residency bound.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// Banks currently resident.
    pub fn resident(&self) -> usize {
        self.banks.len()
    }

    /// Most banks ever resident at once.
    pub fn peak_resident(&self) -> usize {
        self.peak_resident
    }

    /// Checkpoint residency as the scenario list in LRU order (coldest
    /// first) plus the counters.  The banks' θ contents are NOT persisted:
    /// each bank is a pure function of the live `(Params, Cwr)` the
    /// checkpoint restores anyway, so [`BankSet::ckpt_load`] re-derives
    /// them through the normal [`BankSet::ensure`] path (which also
    /// re-warms the backend's marshalled literals and packed panels —
    /// host-side caches a fresh process cannot inherit).
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        let mut order: Vec<(u64, usize)> = self
            .banks
            .iter()
            .map(|b| (b.last_used, b.scenario))
            .collect();
        order.sort_unstable();
        w.usize(order.len());
        for &(_, s) in &order {
            w.usize(s);
        }
        w.u64(self.clock);
        w.u64(self.rebuilds);
        w.u64(self.hits);
        w.u64(self.evictions);
        w.usize(self.peak_resident);
    }

    /// Restore into a freshly built (empty) bank set: re-ensure each
    /// saved scenario coldest-first so relative LRU order — the only thing
    /// eviction decisions depend on — is reconstructed, then overwrite the
    /// counters with the saved values (the re-installs above are resume
    /// mechanics, not simulated work).
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        ctx: &ServeCtx,
    ) -> Result<()> {
        let n = r.usize()?;
        let mut scenarios = Vec::with_capacity(n);
        for _ in 0..n {
            scenarios.push(r.usize()?);
        }
        for s in scenarios {
            self.ensure(s, ctx, false)?;
        }
        self.clock = r.u64()?;
        self.rebuilds = r.u64()?;
        self.hits = r.u64()?;
        self.evictions = r.u64()?;
        self.peak_resident = r.usize()?;
        Ok(())
    }
}

#[cfg(test)]
#[allow(clippy::disallowed_methods)] // tests may unwrap freely
mod tests {
    use super::*;
    use crate::data::benchmarks::Scenario;
    use crate::model::{Cwr, ModelSession};
    use crate::testkit;

    fn scenarios(n: usize) -> Vec<Scenario> {
        (0..n)
            .map(|id| Scenario {
                id,
                classes: vec![id],
                seen: (0..=id).collect(),
                new_pattern: false,
            })
            .collect()
    }

    #[test]
    fn residency_invalidation_and_lru_eviction() {
        let be = testkit::refcpu_backend();
        let sess = ModelSession::new(be.as_ref(), "mbv2").unwrap();
        let mut params = sess.theta0().unwrap();
        let cwr = Cwr::new(&sess.m);
        let scens = scenarios(3);
        let mut banks = BankSet::new(sess.m.classes, 2);

        let ctx = ServeCtx {
            sess: &sess,
            params: &params,
            cwr: &cwr,
            scenarios: &scens,
        };
        assert_eq!(
            banks.ensure(0, &ctx, false).unwrap(),
            BankInstall::Installed { evicted: None }
        );
        assert_eq!(banks.ensure(0, &ctx, false).unwrap(), BankInstall::Hit);
        assert_eq!(
            banks.ensure(1, &ctx, false).unwrap(),
            BankInstall::Installed { evicted: None }
        );
        assert_eq!(banks.resident(), 2);
        // scenario 2 exceeds capacity: the LRU bank (scenario 0) goes
        assert_eq!(
            banks.ensure(2, &ctx, false).unwrap(),
            BankInstall::Installed { evicted: Some(0) }
        );
        assert_eq!(banks.resident(), 2);
        assert_eq!(banks.evictions(), 1);
        assert_eq!(banks.peak_resident(), 2);
        // resident + unchanged generations: hits, zero rebuilds
        assert_eq!(banks.ensure(1, &ctx, false).unwrap(), BankInstall::Hit);
        assert_eq!(banks.ensure(2, &ctx, false).unwrap(), BankInstall::Hit);
        let rebuilds_before = banks.rebuilds();
        // the debug knob forces a rebuild without changing content
        assert_eq!(
            banks.ensure(2, &ctx, true).unwrap(),
            BankInstall::Installed { evicted: None }
        );
        assert_eq!(banks.rebuilds(), rebuilds_before + 1);

        // a live-θ mutation invalidates every resident bank
        params.theta_mut()[0] += 1.0;
        let ctx = ServeCtx {
            sess: &sess,
            params: &params,
            cwr: &cwr,
            scenarios: &scens,
        };
        assert_eq!(
            banks.ensure(1, &ctx, false).unwrap(),
            BankInstall::Installed { evicted: None }
        );
        assert_eq!(banks.params(1).unwrap().theta()[0], params.theta()[0]);
        assert!(banks.params(0).is_err(), "evicted bank is a Result error");
        assert!(banks.resident_params(1).is_some());
        assert!(banks.resident_params(0).is_none());
    }
}
