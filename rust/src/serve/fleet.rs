//! A fleet of serving engines behind one scenario-affinity router.
//!
//! The control plane so far ran ONE [`ServeEngine`] per simulation.  This
//! module scales it sideways: [`Fleet`] fronts `N` independent engines —
//! each with its own [`super::BankSet`], queue, scheduler, and breaker —
//! behind the pure [`FleetRouter`] ([`super::router`]):
//!
//! * arrivals route by **scenario affinity** (an engine whose bank mirror
//!   already holds the scenario keeps getting it, so residency is reused
//!   instead of rebuilt), falling back to least-loaded by queue depth;
//! * a `Dropped{queue-full}` verdict from the affinity target is consumed
//!   as a **cross-engine shedding hint**: the router probes the target
//!   with [`ServeEngine::would_admit`] (pure — nothing is recorded) and
//!   redirects to the least-loaded other engine before the drop is real;
//! * when one engine's share of the fleet-wide queued requests for a
//!   single scenario crosses the rebalance threshold, the router names a
//!   second engine to **warm-install** that scenario's bank on
//!   ([`ServeEngine::warm_bank`]), spreading subsequent affinity routes.
//!
//! Two drivers share that routing logic:
//!
//! * [`Fleet`] — single-threaded, embedded in [`crate::sim::Simulation`]
//!   (`--fleet N`): all engines share the simulation's session/θ through
//!   the per-call [`ServeCtx`], and every engine shares the simulation's
//!   tracer so one timeline covers the whole fleet.  A fleet of one is a
//!   transparent wrapper: same engine calls in the same order, so reports
//!   are bit-identical to a bare [`ServeEngine`] (pinned by
//!   `tests/fleet.rs`).
//! * [`FleetPool`]-style workers via [`run_pool`] — the
//!   [`crate::sim::sweep::ParallelSweeper`] worker-per-backend pattern:
//!   each engine lives on its own thread with its own
//!   [`crate::runtime::Backend`], session, and θ, driven over
//!   command/reply channels.  The coordinator issues polls to every
//!   engine and merges replies in **engine-id order**, so the merged
//!   event stream, histograms, and per-engine trace batches are
//!   bit-identical whether the pool is threaded or sequential
//!   (worker-count independence, pinned by `tests/fleet.rs`).
//!
//! **Determinism contract:** the router is pure and every merge happens
//! in engine-id order; no wall clock, no thread scheduling, no map
//! iteration order ever reaches a decision or an output.

use std::collections::BTreeMap;
use std::sync::mpsc;

use anyhow::{anyhow, Result};

use crate::cost::device::DeviceModel;
use crate::data::benchmarks::Scenario;
use crate::metrics::hist::{HistRegistry, Histogram};
use crate::metrics::ScenarioLatency;
use crate::model::{Cwr, ModelSession, Params};
use crate::runtime::artifact::ModelManifest;
use crate::runtime::{Backend, BackendSpec, FaultPlan, FaultyBackend};
use crate::trace::{self, Event, Tracer};

use super::admission::{Admission, DropReason};
use super::banks::MAX_BANK_CAPACITY;
use super::engine::{ServeCtx, ServeEngine, ServeEvent};
use super::latency::LatencySummary;
use super::queue::QueuedRequest;
use super::router::{FleetRouter, RouterConfig, RouterCounters};
use super::scheduler::Scheduler;
use super::ServeConfig;

/// Fleet knobs (part of [`crate::sim::RunConfig`]).
#[derive(Clone, Copy, Debug)]
pub struct FleetConfig {
    /// Engines in the fleet (`--fleet`; clamped to ≥ 1).  `1` — the
    /// default — routes everything to engine 0 and is bit-identical to
    /// the engine-only control plane.
    pub engines: usize,
    /// Scenario-affinity routing (`--no-affinity` turns it off: pure
    /// least-loaded, the ablation arm of the `repro fleet` table).
    pub affinity: bool,
    /// Hot-scenario share that triggers a second bank install
    /// (`--rebalance-threshold`; `0` disables rebalancing).
    pub rebalance_threshold: f64,
    /// Which engines an active [`FaultPlan`] decorates (`--fault-scope`).
    /// Takes effect in the multi-backend pool runner ([`run_pool`]),
    /// where each engine owns a backend; the in-process simulation
    /// shares one backend across the fleet, so its faults always span
    /// every engine.
    pub fault_scope: FaultScope,
}

impl Default for FleetConfig {
    fn default() -> FleetConfig {
        FleetConfig {
            engines: 1,
            affinity: true,
            rebalance_threshold: 0.5,
            fault_scope: FaultScope::default(),
        }
    }
}

impl FleetConfig {
    fn router(&self) -> RouterConfig {
        RouterConfig {
            affinity: self.affinity,
            rebalance_threshold: self.rebalance_threshold,
        }
    }
}

/// Which engines' backends get the fault decorator (`--fault-scope`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum FaultScope {
    /// Only engine 0 is degraded — one faulty device in an otherwise
    /// healthy fleet (the pre-`--fault-scope` behaviour, unchanged).
    #[default]
    Engine0,
    /// Every engine gets its own [`FaultyBackend`], each drawing an
    /// *independent* fault stream: the plan seed is salted by engine id
    /// ([`engine_fault_seed`]), so engines fail at different times.
    /// Engine 0's stream is bit-identical to `Engine0` scope.
    All,
}

impl FaultScope {
    pub fn parse(s: &str) -> Result<FaultScope> {
        match s {
            "engine0" => Ok(FaultScope::Engine0),
            "all" => Ok(FaultScope::All),
            _ => Err(anyhow!(
                "unknown --fault-scope '{s}' (expected engine0|all)"
            )),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            FaultScope::Engine0 => "engine0",
            FaultScope::All => "all",
        }
    }
}

/// Fault seed for `engine_id` under `FaultScope::All`: the base seed
/// salted by a Weyl step per engine.  Engine 0's multiplier is zero, so
/// its stream — and therefore every `Engine0`-scope result — is unchanged.
pub fn engine_fault_seed(base: u64, engine_id: u64) -> u64 {
    base ^ engine_id.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// N serving engines behind one router, driven inline by the simulation.
pub struct Fleet {
    engines: Vec<ServeEngine>,
    router: FleetRouter,
    /// Rebalance installs decided at arrival time, executed at the next
    /// poll/drain (where a [`ServeCtx`] exists to build the bank from),
    /// as `(engine, scenario)`.
    pending_installs: Vec<(usize, usize)>,
    /// Mirror of `serve.recovery.enabled`: a failed warm install is
    /// absorbed like a failed flush when recovery is on.
    recovery_enabled: bool,
}

impl Fleet {
    pub fn new(
        m: &ModelManifest,
        device: &DeviceModel,
        cfg: &ServeConfig,
        direct: bool,
        disable_serving_cache: bool,
        fleet: &FleetConfig,
    ) -> Fleet {
        let n = fleet.engines.max(1);
        let engines = (0..n)
            .map(|_| {
                ServeEngine::new(m, device, cfg, direct, disable_serving_cache)
            })
            .collect();
        Fleet {
            engines,
            router: FleetRouter::new(
                n,
                cfg.bank_capacity.clamp(1, MAX_BANK_CAPACITY),
                fleet.router(),
            ),
            pending_installs: Vec::new(),
            recovery_enabled: cfg.recovery.enabled,
        }
    }

    pub fn n(&self) -> usize {
        self.engines.len()
    }

    /// The engines, id order (read-only; tests inspect per-engine state).
    pub fn engines(&self) -> &[ServeEngine] {
        &self.engines
    }

    pub fn router_counters(&self) -> RouterCounters {
        self.router.counters()
    }

    /// Share `tracer` with every engine: the whole fleet records into one
    /// ring, so a single timeline covers all engines (the per-engine
    /// track split is the pool's domain — see [`run_pool`]).
    pub fn set_tracer(&mut self, tracer: Tracer) {
        for e in &mut self.engines {
            e.set_tracer(tracer.clone());
        }
    }

    /// Route one arriving request and hand it to the chosen engine.
    /// Only the final target's [`ServeEngine::on_arrival`] runs — the
    /// affinity target is consulted with the pure
    /// [`ServeEngine::would_admit`] probe first, so a queue-full redirect
    /// never double-counts the drop.
    pub fn on_arrival(&mut self, req: QueuedRequest) -> Admission {
        let scenario = req.scenario;
        let dec = self.router.route(scenario);
        let mut target = dec.engine;
        if self.engines.len() > 1 && dec.by_affinity {
            let hint = self.engines[target].would_admit(&req);
            if let Some(alt) = self.router.retry_target(scenario, hint, target)
            {
                target = alt;
            }
        }
        let verdict = self.engines[target].on_arrival(req);
        self.router.note_depth(target, self.engines[target].queue_depth());
        if verdict == Admission::Accepted {
            self.router.on_accept(target, scenario);
            if let Some(install) = self.router.maybe_rebalance() {
                let (s, e) = install;
                self.pending_installs.push((e, s));
            }
        }
        verdict
    }

    /// Poll every engine at `now` in id order (windows/capacity only).
    pub fn poll(&mut self, now: f64, ctx: &ServeCtx) -> Result<Vec<ServeEvent>> {
        self.step(now, ctx, false)
    }

    /// Drain every engine at `now` in id order (window-unconditioned).
    pub fn drain(&mut self, now: f64, ctx: &ServeCtx) -> Result<Vec<ServeEvent>> {
        self.step(now, ctx, true)
    }

    fn step(
        &mut self,
        now: f64,
        ctx: &ServeCtx,
        drain: bool,
    ) -> Result<Vec<ServeEvent>> {
        let mut out = Vec::new();
        for e in 0..self.engines.len() {
            // rebalance installs decided since the last step run first,
            // so the warm bank exists before this step's flushes.
            let mut i = 0;
            while i < self.pending_installs.len() {
                if self.pending_installs[i].0 != e {
                    i += 1;
                    continue;
                }
                let (_, s) = self.pending_installs.remove(i);
                match self.engines[e].warm_bank(s, now, ctx) {
                    Ok(()) => {}
                    // a faulted install costs a cold serve later, never
                    // the run — mirrors the engine's absorbed flushes.
                    Err(_) if self.recovery_enabled => {}
                    Err(err) => return Err(err),
                }
            }
            let events = if drain {
                self.engines[e].drain(now, ctx)?
            } else {
                self.engines[e].poll(now, ctx)?
            };
            for ev in &events {
                match ev {
                    ServeEvent::RequestServed(s) => {
                        self.router.on_departure(e, s.scenario)
                    }
                    // queue-full / slo-infeasible drops happen at arrival
                    // and were never counted as queued; only the
                    // serve-time breaker shed departs a queued request.
                    ServeEvent::RequestDropped {
                        scenario,
                        reason: DropReason::BackendUnavailable,
                        ..
                    } => self.router.on_departure(e, *scenario),
                    _ => {}
                }
            }
            self.router.note_depth(e, self.engines[e].queue_depth());
            out.extend(events);
        }
        Ok(out)
    }

    // -- aggregated views (engine-id order everywhere) -------------------

    pub fn rows_per_request(&self) -> usize {
        self.engines[0].rows_per_request()
    }

    pub fn deadline(&self, t: f64) -> f64 {
        self.engines[0].deadline(t)
    }

    /// Fleet-wide queued requests right now.
    pub fn queue_depth(&self) -> usize {
        self.engines.iter().map(|e| e.queue_depth()).sum()
    }

    /// Sum of per-engine peaks — an upper bound on the true simultaneous
    /// fleet backlog (each engine peaks at its own instant).
    pub fn peak_queue_depth(&self) -> usize {
        self.engines.iter().map(|e| e.peak_queue_depth()).sum()
    }

    /// The primary's scheduler.  Fine-tuning rounds arbitrate against
    /// engine 0 only: the simulation tunes one θ on one device, and the
    /// other engines model extra serving devices that never tune.
    pub fn scheduler(&self) -> &Scheduler {
        self.engines[0].scheduler()
    }

    pub fn scheduler_mut(&mut self) -> &mut Scheduler {
        self.engines[0].scheduler_mut()
    }

    /// Device time spent serving, summed across engines.
    pub fn serve_busy_s(&self) -> f64 {
        self.engines.iter().map(|e| e.scheduler().serve_busy_s()).sum()
    }

    /// Device time spent in fine-tuning rounds (primary only — see
    /// [`Fleet::scheduler`]).
    pub fn round_busy_s(&self) -> f64 {
        self.engines[0].scheduler().round_busy_s()
    }

    pub fn rounds_deferred(&self) -> u64 {
        self.engines[0].scheduler().rounds_deferred()
    }

    pub fn queue_policy_name(&self) -> &'static str {
        self.engines[0].queue_policy_name()
    }

    pub fn served(&self) -> u64 {
        self.engines.iter().map(|e| e.served()).sum()
    }

    pub fn executes(&self) -> u64 {
        self.engines.iter().map(|e| e.executes()).sum()
    }

    pub fn serving_rebuilds(&self) -> u64 {
        self.engines.iter().map(|e| e.serving_rebuilds()).sum()
    }

    pub fn serving_hits(&self) -> u64 {
        self.engines.iter().map(|e| e.serving_hits()).sum()
    }

    pub fn bank_evictions(&self) -> u64 {
        self.engines.iter().map(|e| e.bank_evictions()).sum()
    }

    pub fn banks_peak_resident(&self) -> usize {
        self.engines.iter().map(|e| e.banks_peak_resident()).sum()
    }

    pub fn drops_queue_full(&self) -> u64 {
        self.engines.iter().map(|e| e.drops_queue_full()).sum()
    }

    pub fn drops_slo_infeasible(&self) -> u64 {
        self.engines.iter().map(|e| e.drops_slo_infeasible()).sum()
    }

    pub fn drops_backend_unavailable(&self) -> u64 {
        self.engines.iter().map(|e| e.drops_backend_unavailable()).sum()
    }

    pub fn requests_dropped(&self) -> u64 {
        self.engines.iter().map(|e| e.requests_dropped()).sum()
    }

    pub fn serve_retries(&self) -> u64 {
        self.engines.iter().map(|e| e.serve_retries()).sum()
    }

    pub fn flush_failures(&self) -> u64 {
        self.engines.iter().map(|e| e.flush_failures()).sum()
    }

    pub fn breaker_trips(&self) -> u64 {
        self.engines.iter().map(|e| e.breaker_trips()).sum()
    }

    pub fn degraded_serves(&self) -> u64 {
        self.engines.iter().map(|e| e.degraded_serves()).sum()
    }

    pub fn deadline_misses(&self) -> u64 {
        self.engines.iter().map(|e| e.deadline_misses()).sum()
    }

    /// Fleet-wide mean requests per execute (1.0 when nothing executed).
    pub fn avg_batch_requests(&self) -> f64 {
        let ex = self.executes();
        if ex == 0 {
            1.0
        } else {
            self.served() as f64 / ex as f64
        }
    }

    /// Fleet-wide latency digest: engines' exact sample sets merged in id
    /// order, percentiles recomputed nearest-rank over the union — the
    /// same math [`super::LatencyModel::summary`] applies to one engine,
    /// so a fleet of one is bit-identical to the bare engine's digest.
    pub fn latency_summary(&self) -> LatencySummary {
        let mut hist = Histogram::new();
        let mut violations = 0u64;
        for e in &self.engines {
            hist.merge(e.latency_model().hist());
            violations += e.latency_model().violations();
        }
        let n = hist.count();
        if n == 0 {
            return LatencySummary { attainment: 1.0, ..LatencySummary::default() };
        }
        LatencySummary {
            p50_ms: hist.percentile(50.0) * 1e3,
            p95_ms: hist.percentile(95.0) * 1e3,
            p99_ms: hist.percentile(99.0) * 1e3,
            mean_ms: hist.mean() * 1e3,
            max_ms: hist.max() * 1e3,
            violations,
            attainment: 1.0 - violations as f64 / n as f64,
        }
    }

    /// Per-scenario digests over the merged ledgers (ascending scenario
    /// order; deadline misses summed across engines).
    pub fn per_scenario_latency(&self) -> Vec<ScenarioLatency> {
        let mut merged: BTreeMap<usize, (Histogram, u64)> = BTreeMap::new();
        for e in &self.engines {
            for (s, h, misses) in e.latency_model().scenario_ledgers() {
                let slot =
                    merged.entry(s).or_insert_with(|| (Histogram::new(), 0));
                slot.0.merge(h);
                slot.1 += misses;
            }
        }
        merged
            .into_iter()
            .map(|(scenario, (h, deadline_misses))| ScenarioLatency {
                scenario,
                requests: h.count(),
                mean_ms: h.mean() * 1e3,
                p95_ms: h.percentile(95.0) * 1e3,
                max_ms: h.max() * 1e3,
                deadline_misses,
            })
            .collect()
    }

    /// Merge every engine's distributions into `reg`, engine-id order —
    /// same-key histograms concatenate their exact samples, so the result
    /// is independent of how requests were spread across engines only in
    /// *keys*, and worker-count independent for a fixed routing.
    pub fn fill_hists(&self, reg: &mut HistRegistry) {
        for e in &self.engines {
            let mut tmp = HistRegistry::new();
            e.fill_hists(&mut tmp);
            reg.merge(&tmp);
        }
    }

    /// Checkpoint every engine (id order), the router's bookkeeping, and
    /// any rebalance installs decided but not yet executed.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.usize(self.engines.len());
        for e in &self.engines {
            e.ckpt_save(w);
        }
        self.router.ckpt_save(w);
        w.usize(self.pending_installs.len());
        for &(e, s) in &self.pending_installs {
            w.usize(e);
            w.usize(s);
        }
    }

    /// Restore state saved by [`Fleet::ckpt_save`] into a freshly built
    /// fleet of the same size; banks re-warm from `ctx`'s restored θ.
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        ctx: &ServeCtx,
    ) -> Result<()> {
        let n = r.usize()?;
        if n != self.engines.len() {
            return Err(anyhow!(
                "checkpoint fleet has {n} engines, config has {}",
                self.engines.len()
            ));
        }
        for e in &mut self.engines {
            e.ckpt_load(r, ctx)?;
        }
        self.router.ckpt_load(r)?;
        self.pending_installs.clear();
        let n = r.usize()?;
        for _ in 0..n {
            let e = r.usize()?;
            let s = r.usize()?;
            self.pending_installs.push((e, s));
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// The engine pool: one engine per worker, each with its own backend.
// ---------------------------------------------------------------------------

/// Everything a pool worker needs to build its engine stack.  `Sync` —
/// shared by reference into the worker scope, exactly like
/// [`crate::runtime::BackendSpec`] in the parallel sweeper.
pub struct FleetPoolSpec {
    pub backend: BackendSpec,
    pub model: String,
    pub device: DeviceModel,
    /// Scenario table the engines serve from (cloned per worker).
    pub scenarios: Vec<Scenario>,
    pub serve: ServeConfig,
    pub fleet: FleetConfig,
    /// Give every engine its own enabled tracer; the yield carries the
    /// per-engine event batches for [`crate::trace::chrome_trace_fleet`].
    pub trace: bool,
    /// Fault plan for the scoped engines' backends ([`FaultPlan::none()`]
    /// = no decorator anywhere).
    pub faults: FaultPlan,
    pub fault_seed: u64,
}

/// Fleet-wide counters a pool run yields (fingerprint-excluded
/// observability; `PartialEq` so the sequential-vs-threaded battery can
/// compare them wholesale).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FleetCounters {
    pub served: u64,
    pub executes: u64,
    pub drops_queue_full: u64,
    pub drops_slo_infeasible: u64,
    pub drops_backend_unavailable: u64,
    pub serve_retries: u64,
    pub flush_failures: u64,
    pub breaker_trips: u64,
    pub degraded_serves: u64,
    pub serving_rebuilds: u64,
    pub serving_hits: u64,
    pub bank_evictions: u64,
    pub deadline_misses: u64,
    pub router: RouterCounters,
}

impl FleetCounters {
    fn add(&mut self, other: &FleetCounters) {
        self.served += other.served;
        self.executes += other.executes;
        self.drops_queue_full += other.drops_queue_full;
        self.drops_slo_infeasible += other.drops_slo_infeasible;
        self.drops_backend_unavailable += other.drops_backend_unavailable;
        self.serve_retries += other.serve_retries;
        self.flush_failures += other.flush_failures;
        self.breaker_trips += other.breaker_trips;
        self.degraded_serves += other.degraded_serves;
        self.serving_rebuilds += other.serving_rebuilds;
        self.serving_hits += other.serving_hits;
        self.bank_evictions += other.bank_evictions;
        self.deadline_misses += other.deadline_misses;
        self.router.routed_by_affinity += other.router.routed_by_affinity;
        self.router.routed_least_loaded += other.router.routed_least_loaded;
        self.router.cross_engine_retries += other.router.cross_engine_retries;
        self.router.rebalances += other.router.rebalances;
    }

    pub fn requests_dropped(&self) -> u64 {
        self.drops_queue_full
            + self.drops_slo_infeasible
            + self.drops_backend_unavailable
    }
}

/// What one pool run produced, merged in engine-id order.
pub struct FleetYield {
    /// Every [`ServeEvent`] tagged with its engine, in the coordinator's
    /// deterministic observation order.
    pub events: Vec<(usize, ServeEvent)>,
    /// Per-engine registries merged key-wise in engine-id order.
    pub hists: HistRegistry,
    pub counters: FleetCounters,
    /// Per-engine trace batches (empty `Vec`s when `spec.trace` is off),
    /// ready for [`crate::trace::chrome_trace_fleet`].
    pub trace: Vec<Vec<Event>>,
}

/// One engine's end-of-run yield, sent back over the reply channel.
struct EngineYield {
    hists: HistRegistry,
    counters: FleetCounters,
    trace: Vec<Event>,
}

/// One worker's engine stack: its own session, θ, CWR, and engine over a
/// borrowed backend.  All methods use field-disjoint borrows so the
/// per-call [`ServeCtx`] can reference `sess`/`params`/`cwr` while the
/// engine is borrowed mutably.
struct EngineHost<'b> {
    sess: ModelSession<'b>,
    params: Params,
    cwr: Cwr,
    scenarios: Vec<Scenario>,
    engine: ServeEngine,
    /// Absorb warm-install faults (mirrors `serve.recovery.enabled`).
    absorb_faults: bool,
}

impl<'b> EngineHost<'b> {
    fn new(be: &'b dyn Backend, spec: &FleetPoolSpec) -> Result<EngineHost<'b>> {
        let sess = ModelSession::new(be, &spec.model)?;
        let params = sess.theta0()?;
        let cwr = Cwr::new(&sess.m);
        let mut engine =
            ServeEngine::new(&sess.m, &spec.device, &spec.serve, false, false);
        if spec.trace {
            engine.set_tracer(Tracer::enabled(trace::DEFAULT_CAPACITY));
        }
        Ok(EngineHost {
            sess,
            params,
            cwr,
            scenarios: spec.scenarios.clone(),
            engine,
            absorb_faults: spec.serve.recovery.enabled,
        })
    }

    fn step(&mut self, t: f64, drain: bool) -> Result<Vec<ServeEvent>> {
        let ctx = ServeCtx {
            sess: &self.sess,
            params: &self.params,
            cwr: &self.cwr,
            scenarios: &self.scenarios,
        };
        if drain {
            self.engine.drain(t, &ctx)
        } else {
            self.engine.poll(t, &ctx)
        }
    }

    fn warm(&mut self, t: f64, scenario: usize) -> Result<()> {
        let r = self.engine.warm_bank(
            scenario,
            t,
            &ServeCtx {
                sess: &self.sess,
                params: &self.params,
                cwr: &self.cwr,
                scenarios: &self.scenarios,
            },
        );
        match r {
            Ok(()) => Ok(()),
            Err(_) if self.absorb_faults => Ok(()),
            Err(e) => Err(e),
        }
    }

    fn finish(&mut self) -> EngineYield {
        let mut hists = HistRegistry::new();
        self.engine.fill_hists(&mut hists);
        let e = &self.engine;
        EngineYield {
            hists,
            counters: FleetCounters {
                served: e.served(),
                executes: e.executes(),
                drops_queue_full: e.drops_queue_full(),
                drops_slo_infeasible: e.drops_slo_infeasible(),
                drops_backend_unavailable: e.drops_backend_unavailable(),
                serve_retries: e.serve_retries(),
                flush_failures: e.flush_failures(),
                breaker_trips: e.breaker_trips(),
                degraded_serves: e.degraded_serves(),
                serving_rebuilds: e.serving_rebuilds(),
                serving_hits: e.serving_hits(),
                bank_evictions: e.bank_evictions(),
                deadline_misses: e.deadline_misses(),
                router: RouterCounters::default(),
            },
            trace: e.tracer().take_events(),
        }
    }
}

/// The driver's view of one engine, local or behind channels.  `send_step`
/// / `recv_step` are split so the threaded pool overlaps every engine's
/// poll; the coordinator always collects replies in engine-id order, which
/// is what makes the merged outputs worker-count independent.
trait EnginePort {
    fn probe(&mut self, req: &QueuedRequest) -> Result<Admission>;
    fn arrive(&mut self, req: QueuedRequest) -> Result<(Admission, usize)>;
    fn warm(&mut self, t: f64, scenario: usize) -> Result<()>;
    fn send_step(&mut self, t: f64, drain: bool) -> Result<()>;
    fn recv_step(&mut self) -> Result<(Vec<ServeEvent>, usize)>;
    fn finish(&mut self) -> Result<EngineYield>;
}

/// Sequential port: the host runs inline; `send_step` just parks the
/// request so the recv keeps the exact call order of the threaded pool.
struct LocalPort<'b> {
    host: EngineHost<'b>,
    parked: Option<(f64, bool)>,
}

impl EnginePort for LocalPort<'_> {
    fn probe(&mut self, req: &QueuedRequest) -> Result<Admission> {
        Ok(self.host.engine.would_admit(req))
    }

    fn arrive(&mut self, req: QueuedRequest) -> Result<(Admission, usize)> {
        let verdict = self.host.engine.on_arrival(req);
        Ok((verdict, self.host.engine.queue_depth()))
    }

    fn warm(&mut self, t: f64, scenario: usize) -> Result<()> {
        self.host.warm(t, scenario)
    }

    fn send_step(&mut self, t: f64, drain: bool) -> Result<()> {
        self.parked = Some((t, drain));
        Ok(())
    }

    fn recv_step(&mut self) -> Result<(Vec<ServeEvent>, usize)> {
        let Some((t, drain)) = self.parked.take() else {
            return Err(anyhow!("recv_step without a pending send_step"));
        };
        let events = self.host.step(t, drain)?;
        Ok((events, self.host.engine.queue_depth()))
    }

    fn finish(&mut self) -> Result<EngineYield> {
        Ok(self.host.finish())
    }
}

enum Cmd {
    Probe(QueuedRequest),
    Arrive(QueuedRequest),
    Warm { t: f64, scenario: usize },
    Step { t: f64, drain: bool },
    Finish,
}

enum Reply {
    Verdict(Admission),
    Arrived(Admission, usize),
    Warmed,
    Stepped(Vec<ServeEvent>, usize),
    Finished(Box<EngineYield>),
    Failed(String),
}

/// Threaded port: commands go to the worker, replies come back.  Every
/// method is a strict request/reply pair except the split step.
struct ChanPort {
    tx: mpsc::Sender<Cmd>,
    rx: mpsc::Receiver<Reply>,
}

impl ChanPort {
    fn send(&mut self, cmd: Cmd) -> Result<()> {
        self.tx.send(cmd).map_err(|_| anyhow!("fleet worker hung up"))
    }

    fn recv(&mut self) -> Result<Reply> {
        match self.rx.recv() {
            Ok(Reply::Failed(msg)) => Err(anyhow!("fleet worker failed: {msg}")),
            Ok(reply) => Ok(reply),
            Err(_) => Err(anyhow!("fleet worker died")),
        }
    }
}

impl EnginePort for ChanPort {
    fn probe(&mut self, req: &QueuedRequest) -> Result<Admission> {
        self.send(Cmd::Probe(req.clone()))?;
        match self.recv()? {
            Reply::Verdict(v) => Ok(v),
            _ => Err(anyhow!("fleet worker protocol error (probe)")),
        }
    }

    fn arrive(&mut self, req: QueuedRequest) -> Result<(Admission, usize)> {
        self.send(Cmd::Arrive(req))?;
        match self.recv()? {
            Reply::Arrived(v, depth) => Ok((v, depth)),
            _ => Err(anyhow!("fleet worker protocol error (arrive)")),
        }
    }

    fn warm(&mut self, t: f64, scenario: usize) -> Result<()> {
        self.send(Cmd::Warm { t, scenario })?;
        match self.recv()? {
            Reply::Warmed => Ok(()),
            _ => Err(anyhow!("fleet worker protocol error (warm)")),
        }
    }

    fn send_step(&mut self, t: f64, drain: bool) -> Result<()> {
        self.send(Cmd::Step { t, drain })
    }

    fn recv_step(&mut self) -> Result<(Vec<ServeEvent>, usize)> {
        match self.recv()? {
            Reply::Stepped(events, depth) => Ok((events, depth)),
            _ => Err(anyhow!("fleet worker protocol error (step)")),
        }
    }

    fn finish(&mut self) -> Result<EngineYield> {
        self.send(Cmd::Finish)?;
        match self.recv()? {
            Reply::Finished(y) => Ok(*y),
            _ => Err(anyhow!("fleet worker protocol error (finish)")),
        }
    }
}

/// Worker body: build the engine stack over this worker's own backend
/// (engine 0 optionally behind the fault decorator) and answer commands
/// until the coordinator says finish or hangs up.
fn worker(
    spec: &FleetPoolSpec,
    engine_id: usize,
    rx: mpsc::Receiver<Cmd>,
    tx: mpsc::Sender<Reply>,
) {
    let result = (|| -> Result<()> {
        let be = spec.backend.create()?;
        let decorate = spec.faults.enabled()
            && (engine_id == 0 || spec.fleet.fault_scope == FaultScope::All);
        if decorate {
            let fb = FaultyBackend::new(
                be.as_ref(),
                spec.faults,
                engine_fault_seed(spec.fault_seed, engine_id as u64),
            );
            serve_commands(&fb, spec, rx, &tx)
        } else {
            serve_commands(be.as_ref(), spec, rx, &tx)
        }
    })();
    if let Err(e) = result {
        let _ = tx.send(Reply::Failed(format!("{e:#}")));
    }
}

fn serve_commands(
    be: &dyn Backend,
    spec: &FleetPoolSpec,
    rx: mpsc::Receiver<Cmd>,
    tx: &mpsc::Sender<Reply>,
) -> Result<()> {
    let mut host = EngineHost::new(be, spec)?;
    for cmd in rx {
        let reply = match cmd {
            Cmd::Probe(req) => Reply::Verdict(host.engine.would_admit(&req)),
            Cmd::Arrive(req) => {
                let verdict = host.engine.on_arrival(req);
                Reply::Arrived(verdict, host.engine.queue_depth())
            }
            Cmd::Warm { t, scenario } => {
                host.warm(t, scenario)?;
                Reply::Warmed
            }
            Cmd::Step { t, drain } => {
                let events = host.step(t, drain)?;
                Reply::Stepped(events, host.engine.queue_depth())
            }
            Cmd::Finish => {
                let _ = tx.send(Reply::Finished(Box::new(host.finish())));
                return Ok(());
            }
        };
        tx.send(reply).map_err(|_| anyhow!("fleet coordinator hung up"))?;
    }
    Ok(())
}

/// The routing loop both pool modes share: per arrival, route (with the
/// affinity probe + queue-full retry), deliver, rebalance, then step every
/// engine at the arrival instant — sends fanned out, replies merged in
/// engine-id order.
fn drive<P: EnginePort>(
    ports: &mut [P],
    spec: &FleetPoolSpec,
    workload: &[QueuedRequest],
    drain_t: f64,
) -> Result<FleetYield> {
    let n = ports.len();
    let mut router = FleetRouter::new(
        n,
        spec.serve.bank_capacity.clamp(1, MAX_BANK_CAPACITY),
        spec.fleet.router(),
    );
    let mut events: Vec<(usize, ServeEvent)> = Vec::new();
    for req in workload {
        let t = req.arrival_t;
        let scenario = req.scenario;
        let dec = router.route(scenario);
        let mut target = dec.engine;
        if n > 1 && dec.by_affinity {
            let hint = ports[target].probe(req)?;
            if let Some(alt) = router.retry_target(scenario, hint, target) {
                target = alt;
            }
        }
        let (verdict, depth) = ports[target].arrive(req.clone())?;
        router.note_depth(target, depth);
        if verdict == Admission::Accepted {
            router.on_accept(target, scenario);
            if let Some((s, e)) = router.maybe_rebalance() {
                ports[e].warm(t, s)?;
            }
        }
        step_all(ports, &mut router, &mut events, t, false)?;
    }
    step_all(ports, &mut router, &mut events, drain_t, true)?;

    let mut hists = HistRegistry::new();
    let mut counters = FleetCounters::default();
    let mut trace_batches = Vec::with_capacity(n);
    for port in ports.iter_mut() {
        let y = port.finish()?;
        hists.merge(&y.hists);
        counters.add(&y.counters);
        trace_batches.push(y.trace);
    }
    counters.router = router.counters();
    Ok(FleetYield { events, hists, counters, trace: trace_batches })
}

fn step_all<P: EnginePort>(
    ports: &mut [P],
    router: &mut FleetRouter,
    out: &mut Vec<(usize, ServeEvent)>,
    t: f64,
    drain: bool,
) -> Result<()> {
    for port in ports.iter_mut() {
        port.send_step(t, drain)?;
    }
    for (e, port) in ports.iter_mut().enumerate() {
        let (events, depth) = port.recv_step()?;
        for ev in &events {
            match ev {
                ServeEvent::RequestServed(s) => {
                    router.on_departure(e, s.scenario)
                }
                ServeEvent::RequestDropped {
                    scenario,
                    reason: DropReason::BackendUnavailable,
                    ..
                } => router.on_departure(e, *scenario),
                _ => {}
            }
        }
        router.note_depth(e, depth);
        out.extend(events.into_iter().map(|ev| (e, ev)));
    }
    Ok(())
}

/// Run `workload` (arrival order, ascending `arrival_t`) through a pool
/// of `spec.fleet.engines` engines, then drain at `drain_t`.
///
/// `threaded == false` drives every engine inline; `threaded == true`
/// gives each engine its own worker thread and backend (the parallel
/// sweeper's worker-per-backend pattern).  Both modes produce
/// bit-identical [`FleetYield`]s: the routing is a pure function of the
/// workload, and every merge happens in engine-id order.
pub fn run_pool(
    spec: &FleetPoolSpec,
    workload: &[QueuedRequest],
    drain_t: f64,
    threaded: bool,
) -> Result<FleetYield> {
    let n = spec.fleet.engines.max(1);
    if threaded {
        return std::thread::scope(|scope| {
            let mut ports = Vec::with_capacity(n);
            for e in 0..n {
                let (cmd_tx, cmd_rx) = mpsc::channel::<Cmd>();
                let (reply_tx, reply_rx) = mpsc::channel::<Reply>();
                scope.spawn(move || worker(spec, e, cmd_rx, reply_tx));
                ports.push(ChanPort { tx: cmd_tx, rx: reply_rx });
            }
            let result = drive(&mut ports, spec, workload, drain_t);
            // hang up the command channels so every worker's loop ends
            // (on success they already got Finish; on error this unblocks
            // them) and the scope can join.
            drop(ports);
            result
        });
    }
    let backends: Vec<Box<dyn Backend>> =
        (0..n).map(|_| spec.backend.create()).collect::<Result<_>>()?;
    // per-engine fault decoration must match the threaded pool exactly.
    let faulty: Vec<Option<FaultyBackend>> = backends
        .iter()
        .enumerate()
        .map(|(i, be)| {
            let decorate = spec.faults.enabled()
                && (i == 0 || spec.fleet.fault_scope == FaultScope::All);
            decorate.then(|| {
                FaultyBackend::new(
                    be.as_ref(),
                    spec.faults,
                    engine_fault_seed(spec.fault_seed, i as u64),
                )
            })
        })
        .collect();
    let mut ports: Vec<LocalPort> = Vec::with_capacity(n);
    for (i, be) in backends.iter().enumerate() {
        let be_ref: &dyn Backend = match &faulty[i] {
            Some(f) => f,
            None => be.as_ref(),
        };
        ports.push(LocalPort { host: EngineHost::new(be_ref, spec)?, parked: None });
    }
    drive(&mut ports, spec, workload, drain_t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fleet_config_defaults_to_a_transparent_fleet_of_one() {
        let cfg = FleetConfig::default();
        assert_eq!(cfg.engines, 1);
        assert!(cfg.affinity);
        assert!((cfg.rebalance_threshold - 0.5).abs() < 1e-12);
        let r = cfg.router();
        assert!(r.affinity);
        assert!((r.rebalance_threshold - 0.5).abs() < 1e-12);
    }

    #[test]
    fn fleet_counters_sum_component_wise() {
        let mut a = FleetCounters {
            served: 3,
            executes: 2,
            drops_queue_full: 1,
            ..FleetCounters::default()
        };
        let b = FleetCounters {
            served: 4,
            deadline_misses: 5,
            router: RouterCounters {
                routed_by_affinity: 7,
                routed_least_loaded: 1,
                cross_engine_retries: 2,
                rebalances: 1,
            },
            ..FleetCounters::default()
        };
        a.add(&b);
        assert_eq!(a.served, 7);
        assert_eq!(a.executes, 2);
        assert_eq!(a.drops_queue_full, 1);
        assert_eq!(a.deadline_misses, 5);
        assert_eq!(a.router.routed_by_affinity, 7);
        assert_eq!(a.router.cross_engine_retries, 2);
        assert_eq!(a.requests_dropped(), 1);
    }
}
