//! LazyTune — the inter-tuning optimization (paper §IV-A, Algorithm 1).
//!
//! A fine-tuning round is triggered only when `batches_ava >=
//! batches_needed`.  Three signals steer `batches_needed`:
//!
//! 1. **per-round accuracy gain** (lines 11–12): after each round, fit the
//!    accuracy-iteration curve (NNLS, [`super::curve`]) and set
//!    `batches_needed` to the data volume that should buy a gain comparable
//!    to the last round's — as the model saturates, rounds are delayed and
//!    merged;
//! 2. **inference arrivals** (lines 15–18): on every request,
//!    `d ← d·(1 − 1/ln d)` — the logarithmic backoff [62], less aggressive
//!    than exponential, faster than additive;
//! 3. **scenario change** (lines 20–21): reset to the initial value
//!    (1 batch == immediate fine-tuning) for quick adaptation.

use super::curve;

/// Default cap on how many batches a merged round may wait for.
pub const DEFAULT_CAP: usize = 30;

/// How `batches_needed` shrinks on each inference arrival.  The paper
/// (§IV-A2) picks the logarithmic backoff [62] as the middle ground
/// between the exponential [50] (too aggressive) and additive [22] (too
/// slow) alternatives; all three are implemented for the ablation bench
/// (`etuner repro abl-decay`).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DecayKind {
    /// `d ← d·(1 − 1/ln d)` — the paper's choice.
    Logarithmic,
    /// `d ← d/2` — exponential backoff.
    Exponential,
    /// `d ← d − 1` — additive decrease.
    Additive,
}

#[derive(Clone, Debug)]
pub struct LazyTune {
    /// Current trigger threshold (the paper's `batches_needed`); kept as
    /// f64 because the log-decay is multiplicative.
    batches_needed: f64,
    cap: usize,
    decay: DecayKind,
    /// (cumulative training iterations, validation accuracy) history for
    /// the curve fit — reset at scenario changes (fresh curve per scenario).
    history: Vec<(f64, f64)>,
    last_acc: Option<f64>,
}

impl LazyTune {
    pub fn new(cap: usize) -> LazyTune {
        Self::with_decay(cap, DecayKind::Logarithmic)
    }

    pub fn with_decay(cap: usize, decay: DecayKind) -> LazyTune {
        LazyTune {
            batches_needed: 1.0,
            cap,
            decay,
            history: Vec::new(),
            last_acc: None,
        }
    }

    /// The paper's `batches_needed` (ceil for triggering).
    pub fn batches_needed(&self) -> usize {
        (self.batches_needed.ceil() as usize).clamp(1, self.cap)
    }

    /// Algorithm 1 line 2: trigger once enough data is buffered.
    pub fn should_trigger(&self, batches_ava: usize) -> bool {
        batches_ava >= self.batches_needed()
    }

    /// Algorithm 1 lines 11–12: after a round ends, re-estimate the data
    /// needed for a comparable gain next round.
    pub fn on_round_end(&mut self, total_iterations: u64, val_acc: f64) {
        let gain = self
            .last_acc
            .map(|prev| (val_acc - prev).max(0.0))
            .unwrap_or(1.0);
        self.last_acc = Some(val_acc);
        self.history.push((total_iterations as f64, val_acc));
        if let Some(c) = curve::fit(&self.history) {
            let n = curve::iterations_for_next_gain(
                &c,
                total_iterations as f64,
                gain,
                self.cap,
            );
            self.batches_needed = n as f64;
        }
        // with <3 observations the fit is undefined: stay immediate.
    }

    /// Algorithm 1 lines 15–18: inference arrived — decay the threshold so
    /// frequent requests force fresher models.
    pub fn on_inference(&mut self) {
        let d = self.batches_needed;
        self.batches_needed = match self.decay {
            DecayKind::Logarithmic => {
                if d >= 3.0 {
                    d * (1.0 - 1.0 / d.ln())
                } else {
                    // ln(d) <= 1 makes the formula non-contractive;
                    // saturate low.
                    d.min(2.0).max(1.0) - 0.25
                }
            }
            DecayKind::Exponential => d / 2.0,
            DecayKind::Additive => d - 1.0,
        }
        .max(1.0);
    }

    /// Serving-engine integration: the scheduler deferred a round with
    /// `depth` requests still waiting for the device.  Each *queued*
    /// (arrived-but-unserved) request keeps applying the same per-arrival
    /// decay — real backlog, not the stale-batch proxy, so a sustained
    /// burst pulls the next round forward harder than scattered arrivals.
    /// With batching disabled the queue is always empty and this is never
    /// reached (seed behaviour preserved).
    pub fn on_queue_depth(&mut self, depth: usize) {
        for _ in 0..depth {
            self.on_inference();
        }
    }

    /// Algorithm 1 lines 20–21: scenario change — back to immediate.
    pub fn on_scenario_change(&mut self) {
        self.batches_needed = 1.0;
        self.history.clear();
        self.last_acc = None;
    }

    /// Checkpoint the mutable trigger state.  `cap` and `decay` are
    /// configuration — the resumed run rebuilds them from its (validated)
    /// `RunConfig`, so only the evolving fields are persisted.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.f64(self.batches_needed);
        w.usize(self.history.len());
        for &(iters, acc) in &self.history {
            w.f64(iters);
            w.f64(acc);
        }
        w.opt_f64(self.last_acc);
    }

    /// Restore state saved by [`LazyTune::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        self.batches_needed = r.f64()?;
        let n = r.usize()?;
        let mut history = Vec::with_capacity(n);
        for _ in 0..n {
            let iters = r.f64()?;
            let acc = r.f64()?;
            history.push((iters, acc));
        }
        self.history = history;
        self.last_acc = r.opt_f64()?;
        Ok(())
    }
}

impl Default for LazyTune {
    fn default() -> Self {
        LazyTune::new(DEFAULT_CAP)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_immediate() {
        let lt = LazyTune::default();
        assert_eq!(lt.batches_needed(), 1);
        assert!(lt.should_trigger(1));
        assert!(!lt.should_trigger(0));
    }

    #[test]
    fn saturating_accuracy_grows_threshold() {
        let mut lt = LazyTune::default();
        // saturating curve: gains shrink round over round
        let accs = [0.30, 0.50, 0.60, 0.65, 0.67, 0.68, 0.685, 0.688];
        let mut iters = 0;
        for (i, &a) in accs.iter().enumerate() {
            iters += 1 + i as u64;
            lt.on_round_end(iters, a);
        }
        assert!(
            lt.batches_needed() >= 5,
            "saturated model should wait for more data: {}",
            lt.batches_needed()
        );
    }

    #[test]
    fn inference_pressure_shrinks_threshold() {
        let mut lt = LazyTune::default();
        lt.batches_needed = 20.0;
        let before = lt.batches_needed();
        for _ in 0..6 {
            lt.on_inference();
        }
        assert!(lt.batches_needed() < before);
        // decay follows d(1 - 1/ln d) for d >= 3
        let mut d: f64 = 20.0;
        let mut lt2 = LazyTune::default();
        lt2.batches_needed = d;
        lt2.on_inference();
        d *= 1.0 - 1.0 / d.ln();
        assert!((lt2.batches_needed - d).abs() < 1e-9);
    }

    #[test]
    fn inference_decay_never_below_one() {
        let mut lt = LazyTune::default();
        for _ in 0..100 {
            lt.on_inference();
        }
        assert_eq!(lt.batches_needed(), 1);
    }

    #[test]
    fn queue_depth_pressure_equals_repeated_arrivals() {
        let mut a = LazyTune::default();
        let mut b = LazyTune::default();
        a.batches_needed = 24.0;
        b.batches_needed = 24.0;
        a.on_queue_depth(5);
        for _ in 0..5 {
            b.on_inference();
        }
        assert!((a.batches_needed - b.batches_needed).abs() < 1e-12);
        let before = a.batches_needed;
        a.on_queue_depth(0);
        assert_eq!(a.batches_needed, before, "empty queue applies no pressure");
    }

    #[test]
    fn scenario_change_resets_to_immediate() {
        let mut lt = LazyTune::default();
        lt.batches_needed = 17.0;
        lt.history.push((5.0, 0.5));
        lt.on_scenario_change();
        assert_eq!(lt.batches_needed(), 1);
        assert!(lt.history.is_empty());
    }

    #[test]
    fn decay_kinds_order_by_aggressiveness() {
        // exponential reaches 1 fastest, additive slowest, log in between
        let steps_to_one = |kind: DecayKind| {
            let mut lt = LazyTune::with_decay(64, kind);
            lt.batches_needed = 24.0;
            let mut n = 0;
            while lt.batches_needed() > 1 {
                lt.on_inference();
                n += 1;
                assert!(n < 100);
            }
            n
        };
        let exp = steps_to_one(DecayKind::Exponential);
        let log = steps_to_one(DecayKind::Logarithmic);
        let add = steps_to_one(DecayKind::Additive);
        assert!(exp < log, "exp {exp} !< log {log}");
        assert!(log < add, "log {log} !< add {add}");
    }

    #[test]
    fn threshold_respects_cap() {
        let mut lt = LazyTune::new(8);
        let accs = [0.5, 0.6, 0.62, 0.625, 0.626, 0.6261];
        let mut iters = 0;
        for &a in &accs {
            iters += 3;
            lt.on_round_end(iters, a);
        }
        assert!(lt.batches_needed() <= 8);
    }
}
