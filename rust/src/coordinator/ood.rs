//! Energy-score scenario-change detection (paper §IV-A3, citing Liu et al.
//! [56]): `E(x) = −logsumexp(logits)` is low for in-distribution inputs and
//! rises for out-of-distribution ones.
//!
//! Detection is a robust sliding-window test: keep the last `window` scores,
//! compare each new score against the window's median ± `k`·MAD (median
//! absolute deviation).  Two consecutive outliers flag a scenario change
//! (single spikes are noise); the window is then cleared so the new scenario
//! establishes its own baseline.

#[derive(Clone, Debug)]
pub struct EnergyOod {
    window: Vec<f64>,
    max_window: usize,
    min_baseline: usize,
    k: f64,
    pending_outliers: u32,
    consecutive_needed: u32,
}

impl EnergyOod {
    pub fn new() -> EnergyOod {
        EnergyOod {
            window: Vec::new(),
            // short window: within a scenario the model's confidence keeps
            // growing (energy drifts down), so the baseline must be local.
            max_window: 8,
            min_baseline: 4,
            k: 4.0,
            pending_outliers: 0,
            consecutive_needed: 2,
        }
    }

    fn median(sorted: &[f64]) -> f64 {
        let n = sorted.len();
        if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        }
    }

    /// Feed the mean energy score of one request batch; returns true when
    /// a scenario change is detected (baseline resets afterwards).
    pub fn observe(&mut self, score: f64) -> bool {
        if self.window.len() < self.min_baseline {
            self.window.push(score);
            return false;
        }
        let mut sorted = self.window.clone();
        sorted.sort_by(f64::total_cmp);
        let med = Self::median(&sorted);
        let mut devs: Vec<f64> =
            sorted.iter().map(|v| (v - med).abs()).collect();
        devs.sort_by(f64::total_cmp);
        let mad = Self::median(&devs).max(0.02 * med.abs()).max(1e-3);
        // OOD inputs push the energy score UP (lower confidence); downward
        // drift is in-distribution convergence.  Require both a robust
        // multiple of the local spread and an absolute floor so slow
        // within-scenario wiggle never fires.
        let jump = score - med;
        let outlier = jump > (self.k * mad).max(1.0).max(0.10 * med.abs());
        if outlier {
            self.pending_outliers += 1;
            if self.pending_outliers >= self.consecutive_needed {
                // change confirmed: restart baseline from the new level.
                self.window.clear();
                self.window.push(score);
                self.pending_outliers = 0;
                return true;
            }
            // don't poison the baseline with a suspected outlier.
            return false;
        }
        self.pending_outliers = 0;
        self.window.push(score);
        if self.window.len() > self.max_window {
            self.window.remove(0);
        }
        false
    }

    /// Checkpoint the detector's mutable state (the window and the
    /// consecutive-outlier counter; the thresholds are constants).
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.f64s(&self.window);
        w.u32(self.pending_outliers);
    }

    /// Restore state saved by [`EnergyOod::ckpt_save`].
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> anyhow::Result<()> {
        self.window = r.f64s()?;
        self.pending_outliers = r.u32()?;
        Ok(())
    }
}

impl Default for EnergyOod {
    fn default() -> Self {
        Self::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn stable_stream_never_fires() {
        let mut d = EnergyOod::new();
        let mut rng = Pcg32::new(1, 1);
        for _ in 0..300 {
            assert!(!d.observe(-10.0 + 0.3 * rng.normal() as f64));
        }
    }

    #[test]
    fn level_shift_fires_once_then_restabilizes() {
        let mut d = EnergyOod::new();
        let mut rng = Pcg32::new(2, 2);
        for _ in 0..50 {
            d.observe(-10.0 + 0.2 * rng.normal() as f64);
        }
        let mut fired = 0;
        for _ in 0..30 {
            if d.observe(-4.0 + 0.2 * rng.normal() as f64) {
                fired += 1;
            }
        }
        assert!(fired >= 1, "never detected the shift");
        assert!(fired <= 2, "fired {fired} times for one shift");
    }

    #[test]
    fn detects_multiple_sequential_shifts() {
        let mut d = EnergyOod::new();
        let mut rng = Pcg32::new(3, 3);
        let levels = [-12.0, -6.0, -1.0, 5.0];
        let mut detections = 0;
        for &lvl in &levels {
            for _ in 0..40 {
                if d.observe(lvl + 0.2 * rng.normal() as f64) {
                    detections += 1;
                }
            }
        }
        assert!(detections >= 3, "only {detections} of 3 shifts found");
        assert!(detections <= 4, "{detections} false positives");
    }

    #[test]
    fn single_spike_is_not_a_change() {
        let mut d = EnergyOod::new();
        let mut rng = Pcg32::new(4, 4);
        for _ in 0..30 {
            d.observe(-8.0 + 0.2 * rng.normal() as f64);
        }
        assert!(!d.observe(10.0)); // one spike
        let mut fired = false;
        for _ in 0..20 {
            fired |= d.observe(-8.0 + 0.2 * rng.normal() as f64);
        }
        assert!(!fired, "spike poisoned the detector");
    }

    #[test]
    fn warmup_suppresses_early_firing() {
        let mut d = EnergyOod::new();
        assert!(!d.observe(-10.0));
        assert!(!d.observe(50.0)); // huge jump during warmup: ignored
    }
}
