//! Policy surfaces: when to trigger fine-tuning rounds (inter-tuning) and
//! which layers to freeze (intra-tuning).  ETuner's own policies and the
//! four SOTA baselines ([`crate::baselines`]) plug into the same traits so
//! the simulation engine treats them uniformly (as Table V requires — every
//! baseline is run *with* LazyTune integrated).

use anyhow::Result;

use crate::cost::energy::CostBook;
use crate::cost::flops::FreezeState;
use crate::model::{ModelSession, Params};

use super::lazytune::LazyTune;

/// Inter-tuning (trigger) policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum TunePolicyKind {
    /// Fine-tune the moment a batch arrives (the paper's `Immed.`).
    Immediate,
    /// Static lazy strategy: trigger every `n` batches (Table VII S1–S4).
    Static(usize),
    /// The paper's adaptive LazyTune.
    LazyTune,
}

impl TunePolicyKind {
    pub fn name(&self) -> String {
        match self {
            TunePolicyKind::Immediate => "Immed.".into(),
            TunePolicyKind::Static(n) => format!("Static({n})"),
            TunePolicyKind::LazyTune => "LazyTune".into(),
        }
    }

    pub fn build(&self) -> TunePolicy {
        match self {
            TunePolicyKind::Immediate => TunePolicy::Immediate,
            TunePolicyKind::Static(n) => TunePolicy::Static(*n),
            TunePolicyKind::LazyTune => TunePolicy::Lazy(LazyTune::default()),
        }
    }
}

/// Concrete trigger policy.
#[derive(Clone, Debug)]
pub enum TunePolicy {
    Immediate,
    Static(usize),
    Lazy(LazyTune),
}

impl TunePolicy {
    pub fn should_trigger(&self, batches_ava: usize) -> bool {
        match self {
            TunePolicy::Immediate => batches_ava >= 1,
            TunePolicy::Static(n) => batches_ava >= *n,
            TunePolicy::Lazy(lt) => lt.should_trigger(batches_ava),
        }
    }

    pub fn batches_needed(&self) -> usize {
        match self {
            TunePolicy::Immediate => 1,
            TunePolicy::Static(n) => *n,
            TunePolicy::Lazy(lt) => lt.batches_needed(),
        }
    }

    pub fn on_round_end(&mut self, total_iterations: u64, val_acc: f64) {
        if let TunePolicy::Lazy(lt) = self {
            lt.on_round_end(total_iterations, val_acc);
        }
    }

    pub fn on_inference(&mut self) {
        if let TunePolicy::Lazy(lt) = self {
            lt.on_inference();
        }
    }

    /// Serving backlog observed when the scheduler deferred a round
    /// (request pressure from the real queue depth).
    pub fn on_queue_depth(&mut self, depth: usize) {
        if let TunePolicy::Lazy(lt) = self {
            lt.on_queue_depth(depth);
        }
    }

    pub fn on_scenario_change(&mut self) {
        if let TunePolicy::Lazy(lt) = self {
            lt.on_scenario_change();
        }
    }

    /// Checkpoint the trigger policy: a variant tag plus LazyTune's
    /// mutable state (Immediate/Static carry no evolving state).
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        match self {
            TunePolicy::Immediate => w.u8(0),
            TunePolicy::Static(n) => {
                w.u8(1);
                w.usize(*n);
            }
            TunePolicy::Lazy(lt) => {
                w.u8(2);
                lt.ckpt_save(w);
            }
        }
    }

    /// Restore into a policy built from the *same* configuration: the
    /// variant tag must match (a mismatch means the resume config lied).
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
    ) -> Result<()> {
        let tag = r.u8()?;
        match (tag, &mut *self) {
            (0, TunePolicy::Immediate) => Ok(()),
            (1, TunePolicy::Static(n)) => {
                *n = r.usize()?;
                Ok(())
            }
            (2, TunePolicy::Lazy(lt)) => lt.ckpt_load(r),
            _ => anyhow::bail!(
                "checkpoint tune-policy tag {tag} does not match the \
                 configured policy"
            ),
        }
    }
}

/// Intra-tuning (freezing) policy selector.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum FreezePolicyKind {
    /// Never freeze anything.
    None,
    /// The paper's CKA-guided SimFreeze.
    SimFreeze,
    /// Egeria [88]: module-granularity, strictly front-to-back freezing.
    Egeria,
    /// SlimFit [9]: freeze by weight-update magnitude.
    SlimFit,
    /// RigL [23]: sparse training with drop/grow masks (no freezing).
    RigL,
    /// Ekya [12]: trial-and-error microprofiled freeze configuration.
    Ekya,
}

impl FreezePolicyKind {
    pub fn name(&self) -> &'static str {
        match self {
            FreezePolicyKind::None => "none",
            FreezePolicyKind::SimFreeze => "SimFreeze",
            FreezePolicyKind::Egeria => "Egeria",
            FreezePolicyKind::SlimFit => "SlimFit",
            FreezePolicyKind::RigL => "RigL",
            FreezePolicyKind::Ekya => "Ekya",
        }
    }
}

/// Intra-tuning policy: hooks the engine calls around training.
pub trait FreezePolicy {
    fn name(&self) -> &'static str;

    /// Current freeze decisions (drives artifact choice, lr mask, FLOPs).
    fn state(&self) -> &FreezeState;

    /// First training batch of a (new) scenario arrived — (re)install probe
    /// data and re-evaluate frozen layers.
    fn on_scenario_probe(
        &mut self,
        _sess: &ModelSession,
        _params: &Params,
        _probe: &[f32],
        _book: &mut CostBook,
    ) -> Result<()> {
        Ok(())
    }

    /// Called after every training iteration (may freeze layers, apply
    /// sparsity masks, ...).
    fn after_iteration(
        &mut self,
        _sess: &ModelSession,
        _params: &mut Params,
        _book: &mut CostBook,
    ) -> Result<()> {
        Ok(())
    }

    /// Called when a fine-tuning round completes.
    fn on_round_end(
        &mut self,
        _sess: &ModelSession,
        _params: &mut Params,
        _val_acc: f64,
        _book: &mut CostBook,
    ) -> Result<()> {
        Ok(())
    }

    /// Multiplier on effective compute the device actually saves relative
    /// to the freeze-state accounting (RigL's sparse kernels don't reach
    /// dense efficiency on edge GPUs — paper §V-C).
    fn compute_inefficiency(&self) -> f64 {
        1.0
    }

    /// CKA observations collected so far (SimFreeze with tracing only).
    fn cka_trace(&self) -> Vec<super::simfreeze::CkaSample> {
        vec![]
    }

    /// Serialize this policy's mutable state into a checkpoint payload.
    /// Required (no default) on purpose: a policy added without a codec
    /// would silently break crash-durable resume, so the trait forces the
    /// decision at compile time.
    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter);

    /// Restore state saved by [`FreezePolicy::ckpt_save`] into a policy
    /// freshly built from the same configuration.  `sess` lets policies
    /// holding derived tensors (SimFreeze's reference features) recompute
    /// them instead of persisting them.
    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        sess: &ModelSession,
    ) -> Result<()>;
}

/// The trivial policy: nothing ever freezes.
pub struct NoFreeze {
    state: FreezeState,
}

impl NoFreeze {
    pub fn new(units: usize) -> NoFreeze {
        NoFreeze { state: FreezeState::none(units) }
    }
}

impl FreezePolicy for NoFreeze {
    fn name(&self) -> &'static str {
        "none"
    }

    fn state(&self) -> &FreezeState {
        &self.state
    }

    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        // nothing ever mutates, but persist the freeze vector anyway so a
        // future stateful variant can't silently skip it.
        w.bools(&self.state.frozen);
    }

    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        _sess: &ModelSession,
    ) -> Result<()> {
        self.state.frozen = r.bools()?;
        Ok(())
    }
}

/// SimFreeze adapted to the [`FreezePolicy`] trait.
pub struct SimFreezePolicy {
    inner: super::simfreeze::SimFreeze,
    first_probe_seen: bool,
}

impl SimFreezePolicy {
    pub fn new(inner: super::simfreeze::SimFreeze) -> Self {
        SimFreezePolicy { inner, first_probe_seen: false }
    }

    pub fn inner(&self) -> &super::simfreeze::SimFreeze {
        &self.inner
    }

    pub fn inner_mut(&mut self) -> &mut super::simfreeze::SimFreeze {
        &mut self.inner
    }
}

impl FreezePolicy for SimFreezePolicy {
    fn name(&self) -> &'static str {
        "SimFreeze"
    }

    fn state(&self) -> &FreezeState {
        &self.inner.frozen
    }

    fn on_scenario_probe(
        &mut self,
        sess: &ModelSession,
        params: &Params,
        probe: &[f32],
        book: &mut CostBook,
    ) -> Result<()> {
        if !self.first_probe_seen {
            self.first_probe_seen = true;
            self.inner.set_probe(sess, probe)
        } else {
            self.inner
                .on_scenario_change(sess, params, probe, book)
                .map(|_| ())
        }
    }

    fn after_iteration(
        &mut self,
        sess: &ModelSession,
        params: &mut Params,
        book: &mut CostBook,
    ) -> Result<()> {
        if self.inner.tick(1) {
            self.inner.check_and_freeze(sess, params, book)?;
        }
        Ok(())
    }

    fn cka_trace(&self) -> Vec<super::simfreeze::CkaSample> {
        self.inner.trace.clone()
    }

    fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.bool(self.first_probe_seen);
        self.inner.ckpt_save(w);
    }

    fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        sess: &ModelSession,
    ) -> Result<()> {
        self.first_probe_seen = r.bool()?;
        self.inner.ckpt_load(r, sess)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tune_policy_triggering() {
        assert!(TunePolicyKind::Immediate.build().should_trigger(1));
        let s = TunePolicyKind::Static(5).build();
        assert!(!s.should_trigger(4));
        assert!(s.should_trigger(5));
        let l = TunePolicyKind::LazyTune.build();
        assert!(l.should_trigger(1)); // starts immediate
    }

    #[test]
    fn static_policy_ignores_signals() {
        let mut s = TunePolicyKind::Static(10).build();
        s.on_inference();
        s.on_round_end(50, 0.9);
        s.on_scenario_change();
        assert_eq!(s.batches_needed(), 10);
    }

    #[test]
    fn no_freeze_never_freezes() {
        let nf = NoFreeze::new(6);
        assert_eq!(nf.state().frozen_prefix(), 0);
        assert_eq!(nf.state().trainable_count(), 6);
    }
}
