//! The ETuner coordinator: LazyTune (inter-tuning), SimFreeze
//! (intra-tuning), scenario-change detection, and the policy traits that
//! the SOTA baselines plug into.

pub mod curve;
pub mod lazytune;
pub mod ood;
pub mod policy;
pub mod simfreeze;

pub use lazytune::LazyTune;
pub use ood::EnergyOod;
pub use simfreeze::SimFreeze;
