//! Optimus-style accuracy-vs-iterations curve fitting (paper §IV-A1).
//!
//! After each fine-tuning round LazyTune records `(iterations, validation
//! accuracy)` and fits the non-linear saturation model
//!
//! ```text
//! acc(k) ≈ c0 − c1·(1/k) − c2·(1/k²),     c ≥ 0
//! ```
//!
//! with the NNLS solver ([`crate::nnls`]), exactly the Optimus [70] recipe
//! the paper cites (`scipy.optimize.nnls` [3]).  The fitted curve
//! extrapolates how many more iterations are needed for the next round to
//! match the current round's accuracy gain; as the curve flattens the
//! answer grows and rounds get delayed & merged.

use crate::nnls::{nnls, Mat};

/// Fitted saturation curve.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Curve {
    pub c0: f64,
    pub c1: f64,
    pub c2: f64,
}

impl Curve {
    pub fn eval(&self, k: f64) -> f64 {
        let k = k.max(1.0);
        self.c0 - self.c1 / k - self.c2 / (k * k)
    }
}

/// Fit the curve to `(iterations, accuracy)` observations.  Returns `None`
/// with fewer than 3 points (the caller falls back to immediate tuning,
/// matching the paper's "initial value = 1 batch").
pub fn fit(points: &[(f64, f64)]) -> Option<Curve> {
    if points.len() < 3 {
        return None;
    }
    // Parameterize acc = c0 - c1/k - c2/k^2 with all c >= 0:
    //   acc = [1, -1/k, -1/k^2] . c  — flip signs into the basis so the
    // NNLS nonnegativity constraint expresses "monotone saturating".
    let rows: Vec<Vec<f64>> = points
        .iter()
        .map(|&(k, _)| {
            let k = k.max(1.0);
            vec![1.0, -1.0 / k, -1.0 / (k * k)]
        })
        .collect();
    let b: Vec<f64> = points.iter().map(|&(_, a)| a).collect();
    let a = Mat::from_rows(&rows);
    let x = nnls(&a, &b);
    Some(Curve { c0: x[0], c1: x[1], c2: x[2] })
}

/// Given the fit, the current iteration count, and the gain achieved by the
/// last round, estimate how many iterations the next round needs to achieve
/// a comparable gain.  Clamped to `[1, cap]`.
pub fn iterations_for_next_gain(
    curve: &Curve,
    k_now: f64,
    last_gain: f64,
    cap: usize,
) -> usize {
    let target = (last_gain * 0.9).max(1e-4); // match ~90% of last gain
    let base = curve.eval(k_now);
    for n in 1..=cap {
        if curve.eval(k_now + n as f64) - base >= target {
            return n;
        }
    }
    cap
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth_points(c0: f64, c1: f64, c2: f64, ks: &[f64]) -> Vec<(f64, f64)> {
        let c = Curve { c0, c1, c2 };
        ks.iter().map(|&k| (k, c.eval(k))).collect()
    }

    #[test]
    fn recovers_exact_curve() {
        let pts = synth_points(0.8, 0.5, 0.2, &[1.0, 2.0, 4.0, 8.0, 16.0]);
        let c = fit(&pts).unwrap();
        assert!((c.c0 - 0.8).abs() < 1e-6, "{c:?}");
        assert!((c.c1 - 0.5).abs() < 1e-5, "{c:?}");
        assert!((c.c2 - 0.2).abs() < 1e-4, "{c:?}");
    }

    #[test]
    fn too_few_points_is_none() {
        assert!(fit(&[(1.0, 0.5), (2.0, 0.6)]).is_none());
    }

    #[test]
    fn curve_is_monotone_increasing_with_nonneg_coeffs() {
        let pts = synth_points(0.9, 0.4, 0.1, &[1.0, 3.0, 5.0, 9.0, 20.0]);
        let c = fit(&pts).unwrap();
        let mut prev = f64::NEG_INFINITY;
        for k in 1..200 {
            let v = c.eval(k as f64);
            assert!(v >= prev - 1e-12);
            prev = v;
        }
        assert!(c.c0 >= 0.0 && c.c1 >= 0.0 && c.c2 >= 0.0);
    }

    #[test]
    fn saturated_curve_requests_many_iterations() {
        // flat curve: each extra iteration adds almost nothing
        let c = Curve { c0: 0.8, c1: 0.01, c2: 0.0 };
        let n_late = iterations_for_next_gain(&c, 100.0, 0.05, 30);
        assert_eq!(n_late, 30, "should hit the cap when saturated");
    }

    #[test]
    fn steep_curve_requests_few_iterations() {
        let c = Curve { c0: 0.8, c1: 2.0, c2: 0.0 };
        // at k=2 the curve still climbs fast; small gain target is quick
        let n = iterations_for_next_gain(&c, 2.0, 0.05, 30);
        assert!(n <= 3, "steep curve wanted {n}");
    }

    #[test]
    fn noisy_fit_is_reasonable() {
        // points with small perturbations still give a saturating fit
        let mut pts = synth_points(0.7, 0.6, 0.0, &[1., 2., 3., 5., 8., 13.]);
        for (i, p) in pts.iter_mut().enumerate() {
            p.1 += if i % 2 == 0 { 0.01 } else { -0.01 };
        }
        let c = fit(&pts).unwrap();
        assert!((c.eval(100.0) - 0.7).abs() < 0.05);
    }
}
