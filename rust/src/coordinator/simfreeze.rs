//! SimFreeze — the intra-tuning optimization (paper §IV-B, Algorithm 1).
//!
//! Every `freeze_interval` training iterations, compute each *active*
//! layer's CKA between the model being tuned and the deployment-time
//! reference model on the scenario's probe batch (the first training batch
//! that arrived in the scenario).  A layer whose CKA variation rate drops
//! below the stability threshold has converged and is frozen.  On a
//! scenario change, frozen layers are re-probed with new-scenario data and
//! the ones whose CKA moved are unfrozen (front layers doing task-agnostic
//! feature extraction usually stay frozen).
//!
//! The CKA itself runs through the Pallas Gram-kernel artifact
//! ([`crate::model::ModelSession::cka`]); its energy cost is charged to the
//! ledger and reported (<2% of total in the paper, validated in tab-level
//! benches).

use anyhow::Result;

use crate::cost::energy::CostBook;
use crate::cost::flops::FreezeState;
use crate::model::{ModelSession, Params};
use crate::runtime::exec::TensorF32;

/// One CKA observation (kept for the Fig. 5 reproduction).
#[derive(Clone, Copy, Debug)]
pub struct CkaSample {
    pub iteration: u64,
    pub layer: usize,
    pub cka: f32,
}

#[derive(Clone, Debug)]
pub struct SimFreeze {
    pub freeze_interval: u64,
    pub cka_th: f64,
    pub frozen: FreezeState,
    /// last CKA value per feature layer (embed + blocks; head excluded).
    last_cka: Vec<Option<f32>>,
    probe: Option<Vec<f32>>,
    ref_feats: Option<TensorF32>,
    /// Reference (initial, pre-fine-tuning) parameters, held as `Params`
    /// once so probing reuses the session's cached θ literal instead of
    /// cloning the full vector every scenario change.
    ref_params: Params,
    iters_since_check: u64,
    total_iters: u64,
    pub trace: Vec<CkaSample>,
    pub keep_trace: bool,
}

impl SimFreeze {
    /// `units` = freeze units of the model; `ref_theta` = the reference
    /// (initial, pre-fine-tuning) parameters.
    pub fn new(units: usize, ref_theta: Vec<f32>, freeze_interval: u64, cka_th: f64) -> SimFreeze {
        SimFreeze {
            freeze_interval,
            cka_th,
            frozen: FreezeState::none(units),
            last_cka: vec![None; units - 1],
            probe: None,
            ref_feats: None,
            ref_params: Params::from_vec(ref_theta),
            iters_since_check: 0,
            total_iters: 0,
            trace: Vec::new(),
            keep_trace: false,
        }
    }

    fn feature_layers(&self) -> usize {
        self.frozen.units() - 1
    }

    /// Install the scenario's CKA probe batch (Algorithm 1 line 22: the
    /// first training batch that arrives in a scenario).
    pub fn set_probe(&mut self, sess: &ModelSession, x: &[f32]) -> Result<()> {
        self.ref_feats = Some(sess.features(&self.ref_params, x)?);
        self.probe = Some(x.to_vec());
        Ok(())
    }

    pub fn has_probe(&self) -> bool {
        self.probe.is_some()
    }

    /// Record `n` training iterations; returns true if a CKA check is due.
    pub fn tick(&mut self, n: u64) -> bool {
        self.iters_since_check += n;
        self.total_iters += n;
        self.probe.is_some() && self.iters_since_check >= self.freeze_interval
    }

    /// Algorithm 1 lines 5–9: probe active layers, freeze the stable ones.
    /// Returns the layers newly frozen.
    pub fn check_and_freeze(
        &mut self,
        sess: &ModelSession,
        params: &Params,
        book: &mut CostBook,
    ) -> Result<Vec<usize>> {
        self.iters_since_check = 0;
        let probe = self.probe.as_ref().expect("probe installed");
        let active = (0..self.feature_layers())
            .filter(|&l| !self.frozen.frozen[l])
            .count();
        if active == 0 {
            return Ok(vec![]);
        }
        book.charge_cka_probe(&sess.m, active);
        let feats = sess.features(params, probe)?;
        let ref_feats = self.ref_feats.as_ref().unwrap();
        let mut newly = vec![];
        for l in 0..self.feature_layers() {
            if self.frozen.frozen[l] {
                continue;
            }
            let cka = sess.cka_layer(&feats, ref_feats, l)?;
            if self.keep_trace {
                self.trace.push(CkaSample { iteration: self.total_iters, layer: l, cka });
            }
            if let Some(prev) = self.last_cka[l] {
                let variation = ((cka - prev) / prev.abs().max(1e-6)).abs() as f64;
                if variation <= self.cka_th {
                    self.frozen.frozen[l] = true;
                    newly.push(l);
                }
            }
            self.last_cka[l] = Some(cka);
        }
        Ok(newly)
    }

    /// Algorithm 1 lines 20–26: scenario change — new probe data, re-check
    /// every frozen layer and unfreeze the unstable ones.  Returns the
    /// layers unfrozen.
    pub fn on_scenario_change(
        &mut self,
        sess: &ModelSession,
        params: &Params,
        new_probe: &[f32],
        book: &mut CostBook,
    ) -> Result<Vec<usize>> {
        self.set_probe(sess, new_probe)?;
        let frozen_layers = (0..self.feature_layers())
            .filter(|&l| self.frozen.frozen[l])
            .count();
        let mut unfrozen = vec![];
        if frozen_layers > 0 {
            book.charge_cka_probe(&sess.m, frozen_layers);
            let feats = sess.features(params, new_probe)?;
            let ref_feats = self.ref_feats.as_ref().unwrap();
            for l in 0..self.feature_layers() {
                if !self.frozen.frozen[l] {
                    continue;
                }
                let cka = sess.cka_layer(&feats, ref_feats, l)?;
                if let Some(prev) = self.last_cka[l] {
                    let variation =
                        ((cka - prev) / prev.abs().max(1e-6)).abs() as f64;
                    if variation > self.cka_th {
                        self.frozen.frozen[l] = false;
                        unfrozen.push(l);
                    }
                }
                self.last_cka[l] = Some(cka);
            }
        }
        self.iters_since_check = 0;
        Ok(unfrozen)
    }

    /// Checkpoint the evolving CKA state.  `ref_params` is NOT persisted:
    /// it is the deterministic post-warmup θ, and the resumed process
    /// reconstructs it identically when it rebuilds the simulation.
    /// `ref_feats` is derived (reference features on the current probe),
    /// so [`SimFreeze::ckpt_load`] recomputes it instead.
    pub fn ckpt_save(&self, w: &mut crate::ckpt::ByteWriter) {
        w.bools(&self.frozen.frozen);
        w.usize(self.last_cka.len());
        for &c in &self.last_cka {
            w.opt_f32(c);
        }
        match &self.probe {
            Some(p) => {
                w.bool(true);
                w.f32s(p);
            }
            None => w.bool(false),
        }
        w.u64(self.iters_since_check);
        w.u64(self.total_iters);
        w.bool(self.keep_trace);
        w.usize(self.trace.len());
        for s in &self.trace {
            w.u64(s.iteration);
            w.usize(s.layer);
            w.f32(s.cka);
        }
    }

    /// Restore state saved by [`SimFreeze::ckpt_save`], recomputing the
    /// reference features from the restored probe (pure derived data — no
    /// energy is charged, matching [`SimFreeze::set_probe`]).
    pub fn ckpt_load(
        &mut self,
        r: &mut crate::ckpt::ByteReader,
        sess: &ModelSession,
    ) -> Result<()> {
        self.frozen.frozen = r.bools()?;
        let n = r.usize()?;
        let mut last_cka = Vec::with_capacity(n);
        for _ in 0..n {
            last_cka.push(r.opt_f32()?);
        }
        self.last_cka = last_cka;
        if r.bool()? {
            let p = r.f32s()?;
            self.ref_feats = Some(sess.features(&self.ref_params, &p)?);
            self.probe = Some(p);
        } else {
            self.ref_feats = None;
            self.probe = None;
        }
        self.iters_since_check = r.u64()?;
        self.total_iters = r.u64()?;
        self.keep_trace = r.bool()?;
        let n = r.usize()?;
        let mut trace = Vec::with_capacity(n);
        for _ in 0..n {
            trace.push(CkaSample {
                iteration: r.u64()?,
                layer: r.usize()?,
                cka: r.f32()?,
            });
        }
        self.trace = trace;
        Ok(())
    }
}
