//! Crash-durable checkpointing and recovery (PR 9).
//!
//! The simulation is deterministic: every draw derives from `(benchmark,
//! seed)` through seeded [`Pcg32`] streams, and fine-tuning **round
//! boundaries are quiesce points** — the batch buffer was just drained by
//! the round, every serve queue was drained before the round was allowed
//! to proceed, injected spike delay was consumed, and pending bank
//! installs were absorbed.  A snapshot of the mutable state taken exactly
//! there, plus the index of the last fully-processed stream event, is
//! therefore enough to reconstruct the run *bit-identically*: the resumed
//! process re-derives everything static (stream events, schedule, probes)
//! from the config, restores the mutable state, and re-executes the
//! remaining events — by induction the scientific fingerprint equals the
//! uncrashed run's.
//!
//! # On-disk layout (`--checkpoint-dir`)
//!
//! * `snapshot.bin` — one framed record, rewritten atomically (temp file +
//!   rename) every `--checkpoint-every` (`Nr` rounds / `Ss` virtual
//!   seconds; default `1r`).
//! * `snapshot.prev.bin` — the previous snapshot, rotated aside before
//!   each overwrite: the fallback target when the newest record is
//!   corrupt.
//! * `journal.bin` — append-only framed records for the round boundaries
//!   *between* snapshots; truncated whenever a new snapshot lands.  A
//!   record is a full self-contained state (not a delta), so "replay" =
//!   apply the newest valid record.
//!
//! Every record is framed `[magic][round][len][fnv64][payload]`; a torn
//! tail or flipped bit fails the checksum and recovery falls back to the
//! next-newest valid record, counting a fallback.  The fault grammar
//! (`--faults`) drives both deterministic crashes (`crash:after-round-N`,
//! `crash:t=S`, seeded `crash:R` — evaluated by the simulation at round
//! boundaries, *after* the boundary's record is written) and checkpoint
//! corruption (`ckpt-flip:N`, `ckpt-torn:N` — applied by
//! [`CheckpointWriter`] to the Nth record it frames).
//!
//! With no `--checkpoint-dir` (the default) none of this is constructed:
//! the run takes the exact pre-PR-9 path and reports stay bit-identical.

use std::fmt;
use std::fs;
use std::io::Write as _;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::metrics::hist::{HistRegistry, Histogram};
use crate::metrics::{Report, RequestRecord, RoundRecord, ScenarioLatency};
use crate::rng::Pcg32;
use crate::runtime::FaultPlan;

// ---------------------------------------------------------------------------
// byte codec
// ---------------------------------------------------------------------------

/// Little-endian append-only byte sink for checkpoint payloads.  Floats
/// serialize via `to_bits`, so round-trips are bit-exact.
#[derive(Default)]
pub struct ByteWriter {
    buf: Vec<u8>,
}

impl ByteWriter {
    pub fn new() -> ByteWriter {
        ByteWriter::default()
    }

    pub fn into_vec(self) -> Vec<u8> {
        self.buf
    }

    pub fn len(&self) -> usize {
        self.buf.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    pub fn u8(&mut self, v: u8) {
        self.buf.push(v);
    }

    pub fn bool(&mut self, v: bool) {
        self.buf.push(v as u8);
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn i32(&mut self, v: i32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn usize(&mut self, v: usize) {
        self.u64(v as u64);
    }

    pub fn f32(&mut self, v: f32) {
        self.u32(v.to_bits());
    }

    pub fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }

    pub fn str(&mut self, s: &str) {
        self.bytes(s.as_bytes());
    }

    /// Length-prefixed raw bytes.
    pub fn bytes(&mut self, b: &[u8]) {
        self.usize(b.len());
        self.buf.extend_from_slice(b);
    }

    pub fn f32s(&mut self, v: &[f32]) {
        self.usize(v.len());
        for &x in v {
            self.f32(x);
        }
    }

    pub fn f64s(&mut self, v: &[f64]) {
        self.usize(v.len());
        for &x in v {
            self.f64(x);
        }
    }

    pub fn i32s(&mut self, v: &[i32]) {
        self.usize(v.len());
        for &x in v {
            self.i32(x);
        }
    }

    pub fn u32s(&mut self, v: &[u32]) {
        self.usize(v.len());
        for &x in v {
            self.u32(x);
        }
    }

    pub fn u64s(&mut self, v: &[u64]) {
        self.usize(v.len());
        for &x in v {
            self.u64(x);
        }
    }

    pub fn usizes(&mut self, v: &[usize]) {
        self.usize(v.len());
        for &x in v {
            self.usize(x);
        }
    }

    pub fn bools(&mut self, v: &[bool]) {
        self.usize(v.len());
        for &x in v {
            self.bool(x);
        }
    }

    pub fn opt_f32(&mut self, v: Option<f32>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f32(x);
            }
            None => self.bool(false),
        }
    }

    pub fn opt_f64(&mut self, v: Option<f64>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.f64(x);
            }
            None => self.bool(false),
        }
    }

    pub fn opt_usize(&mut self, v: Option<usize>) {
        match v {
            Some(x) => {
                self.bool(true);
                self.usize(x);
            }
            None => self.bool(false),
        }
    }
}

/// Cursor over a checkpoint payload; every read is bounds-checked so a
/// truncated or foreign blob surfaces as an error, never a panic.
pub struct ByteReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteReader<'a> {
    pub fn new(buf: &'a [u8]) -> ByteReader<'a> {
        ByteReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!(
                "checkpoint payload truncated: need {n} bytes at offset {}, \
                 have {}",
                self.pos,
                self.buf.len() - self.pos
            );
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    pub fn bool(&mut self) -> Result<bool> {
        Ok(self.u8()? != 0)
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn usize(&mut self) -> Result<usize> {
        Ok(self.u64()? as usize)
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_bits(self.u64()?))
    }

    pub fn str(&mut self) -> Result<String> {
        let b = self.bytes_raw()?;
        String::from_utf8(b.to_vec()).context("checkpoint string not utf-8")
    }

    fn bytes_raw(&mut self) -> Result<&'a [u8]> {
        let n = self.usize()?;
        self.take(n)
    }

    pub fn bytes(&mut self) -> Result<Vec<u8>> {
        Ok(self.bytes_raw()?.to_vec())
    }

    pub fn f32s(&mut self) -> Result<Vec<f32>> {
        let n = self.usize()?;
        (0..n).map(|_| self.f32()).collect()
    }

    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.usize()?;
        (0..n).map(|_| self.f64()).collect()
    }

    pub fn i32s(&mut self) -> Result<Vec<i32>> {
        let n = self.usize()?;
        (0..n).map(|_| self.i32()).collect()
    }

    pub fn u32s(&mut self) -> Result<Vec<u32>> {
        let n = self.usize()?;
        (0..n).map(|_| self.u32()).collect()
    }

    pub fn u64s(&mut self) -> Result<Vec<u64>> {
        let n = self.usize()?;
        (0..n).map(|_| self.u64()).collect()
    }

    pub fn usizes(&mut self) -> Result<Vec<usize>> {
        let n = self.usize()?;
        (0..n).map(|_| self.usize()).collect()
    }

    pub fn bools(&mut self) -> Result<Vec<bool>> {
        let n = self.usize()?;
        (0..n).map(|_| self.bool()).collect()
    }

    pub fn opt_f32(&mut self) -> Result<Option<f32>> {
        Ok(if self.bool()? { Some(self.f32()?) } else { None })
    }

    pub fn opt_f64(&mut self) -> Result<Option<f64>> {
        Ok(if self.bool()? { Some(self.f64()?) } else { None })
    }

    pub fn opt_usize(&mut self) -> Result<Option<usize>> {
        Ok(if self.bool()? { Some(self.usize()?) } else { None })
    }

    /// Every byte consumed?  A payload with trailing garbage is a format
    /// skew (old binary reading a new checkpoint) and must be rejected.
    pub fn expect_end(&self) -> Result<()> {
        if self.pos != self.buf.len() {
            bail!(
                "checkpoint payload has {} unread trailing bytes",
                self.buf.len() - self.pos
            );
        }
        Ok(())
    }
}

/// FNV-1a over a byte slice — same constants as
/// [`Report::fingerprint`], reused as the record checksum.
pub fn fnv64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

// ---------------------------------------------------------------------------
// framed records
// ---------------------------------------------------------------------------

/// Frame magic: "ETK1".
const MAGIC: u32 = 0x314B_5445;
/// Frame header: magic(4) + round(8) + len(8) + checksum(8).
const HEADER_LEN: usize = 28;

/// Frame one record: `[magic][round][len][fnv64(payload)][payload]`.
/// `round` doubles as the sweep journal's cell digest.
pub fn frame(round: u64, payload: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(HEADER_LEN + payload.len());
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.extend_from_slice(&round.to_le_bytes());
    out.extend_from_slice(&(payload.len() as u64).to_le_bytes());
    out.extend_from_slice(&fnv64(payload).to_le_bytes());
    out.extend_from_slice(payload);
    out
}

/// One checksum-valid record recovered from a file.
pub struct ScannedRecord {
    pub round: u64,
    pub payload: Vec<u8>,
}

/// All records scanned out of one file, in file (write) order.
pub struct ScanOutcome {
    pub records: Vec<ScannedRecord>,
    /// Frames that failed validation: bad checksum (bit flip), bad magic,
    /// or a torn tail (partial final frame).
    pub bad: u64,
}

/// Walk a record file front to back.  A checksum failure on an intact
/// frame skips just that record (frame boundaries survive bit flips); a
/// torn tail or corrupted header ends the scan.
pub fn scan(bytes: &[u8]) -> ScanOutcome {
    let mut records = Vec::new();
    let mut bad = 0u64;
    let mut pos = 0usize;
    while pos < bytes.len() {
        if pos + HEADER_LEN > bytes.len() {
            bad += 1; // torn header
            break;
        }
        let word =
            |i: usize| u64::from_le_bytes(bytes[i..i + 8].try_into().unwrap());
        let magic =
            u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap());
        if magic != MAGIC {
            bad += 1; // lost framing: cannot resync reliably
            break;
        }
        let round = word(pos + 4);
        let len = word(pos + 12) as usize;
        let sum = word(pos + 20);
        let start = pos + HEADER_LEN;
        if start + len > bytes.len() {
            bad += 1; // torn payload
            break;
        }
        let payload = &bytes[start..start + len];
        if fnv64(payload) == sum {
            records.push(ScannedRecord { round, payload: payload.to_vec() });
        } else {
            bad += 1; // bit flip
        }
        pos = start + len;
    }
    ScanOutcome { records, bad }
}

/// Read a record file, treating a missing file as empty.
fn scan_file(path: &Path) -> Result<ScanOutcome> {
    match fs::read(path) {
        Ok(bytes) => Ok(scan(&bytes)),
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            Ok(ScanOutcome { records: Vec::new(), bad: 0 })
        }
        Err(e) => {
            Err(e).with_context(|| format!("reading {}", path.display()))
        }
    }
}

// ---------------------------------------------------------------------------
// checkpoint writer
// ---------------------------------------------------------------------------

pub const SNAPSHOT: &str = "snapshot.bin";
pub const SNAPSHOT_PREV: &str = "snapshot.prev.bin";
pub const JOURNAL: &str = "journal.bin";

/// Snapshot cadence: `Nr` = every N fine-tuning rounds, `Ss` = every S
/// virtual seconds.  Boundaries between snapshots go to the journal.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Cadence {
    Rounds(u64),
    Seconds(f64),
}

impl Default for Cadence {
    fn default() -> Self {
        Cadence::Rounds(1)
    }
}

impl Cadence {
    /// Parse the `--checkpoint-every` grammar: `3r` / `120s`.
    pub fn parse(s: &str) -> Result<Cadence> {
        let s = s.trim();
        if let Some(n) =
            s.strip_suffix('r').or_else(|| s.strip_suffix('R'))
        {
            let n: u64 = n
                .parse()
                .map_err(|_| anyhow::anyhow!("bad round count {n:?}"))?;
            if n == 0 {
                bail!("checkpoint cadence needs >= 1 round");
            }
            return Ok(Cadence::Rounds(n));
        }
        if let Some(v) =
            s.strip_suffix('s').or_else(|| s.strip_suffix('S'))
        {
            let v: f64 = v
                .parse()
                .map_err(|_| anyhow::anyhow!("bad seconds {v:?}"))?;
            if v <= 0.0 {
                bail!("checkpoint cadence needs > 0 seconds");
            }
            return Ok(Cadence::Seconds(v));
        }
        bail!(
            "bad checkpoint cadence {s:?} (expected Nr rounds or Ss virtual \
             seconds, e.g. 3r or 120s)"
        )
    }
}

impl fmt::Display for Cadence {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Cadence::Rounds(n) => write!(f, "{n}r"),
            Cadence::Seconds(s) => write!(f, "{s}s"),
        }
    }
}

/// Checkpointing knobs carried on `RunConfig`.  The default (`dir: None`)
/// disables the subsystem entirely.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CheckpointConfig {
    /// Checkpoint directory (`--checkpoint-dir`); `None` = off.
    pub dir: Option<PathBuf>,
    /// Snapshot cadence (`--checkpoint-every`, default `1r`).
    pub every: Cadence,
    /// Entry came through `--resume`: restore from `dir` before running.
    pub resume: bool,
}

/// Writes one framed record per round boundary: snapshots on the cadence
/// (atomic temp-file + rename, previous snapshot rotated to
/// [`SNAPSHOT_PREV`], journal truncated), journal appends in between.
/// Applies the plan's `ckpt-flip`/`ckpt-torn` corruption to the Nth
/// record framed, counting every record through this writer.
pub struct CheckpointWriter {
    dir: PathBuf,
    every: Cadence,
    flip: u64,
    torn: u64,
    /// Records framed so far (ordinal for corruption targeting).
    framed: u64,
    last_snapshot_round: Option<u64>,
    last_snapshot_t: f64,
    /// Counters surfaced on the report (fingerprint-excluded).
    pub written: u64,
    pub bytes: u64,
}

impl CheckpointWriter {
    pub fn new(
        dir: &Path,
        every: Cadence,
        plan: &FaultPlan,
    ) -> Result<CheckpointWriter> {
        fs::create_dir_all(dir).with_context(|| {
            format!("creating checkpoint dir {}", dir.display())
        })?;
        Ok(CheckpointWriter {
            dir: dir.to_path_buf(),
            every,
            flip: plan.ckpt_flip,
            torn: plan.ckpt_torn,
            framed: 0,
            last_snapshot_round: None,
            last_snapshot_t: f64::NEG_INFINITY,
            written: 0,
            bytes: 0,
        })
    }

    fn snapshot_due(&self, round: u64, t: f64) -> bool {
        match self.every {
            Cadence::Rounds(n) => match self.last_snapshot_round {
                None => true,
                Some(last) => round.saturating_sub(last) >= n,
            },
            Cadence::Seconds(s) => {
                self.last_snapshot_round.is_none()
                    || t - self.last_snapshot_t >= s
            }
        }
    }

    /// Frame + scheduled corruption.  `ckpt-flip:N` flips one payload bit
    /// of the Nth record; `ckpt-torn:N` truncates its write midway —
    /// both leave earlier records intact so recovery can fall back.
    fn frame_corrupted(&mut self, round: u64, payload: &[u8]) -> Vec<u8> {
        let mut f = frame(round, payload);
        self.framed += 1;
        if self.flip == self.framed {
            let mid = HEADER_LEN + payload.len() / 2;
            f[mid.min(f.len() - 1)] ^= 0x10;
        }
        if self.torn == self.framed {
            f.truncate(HEADER_LEN.min(f.len() / 2).max(1));
        }
        f
    }

    /// Persist one round boundary's state.  Returns the bytes written.
    pub fn on_boundary(
        &mut self,
        round: u64,
        t: f64,
        payload: &[u8],
    ) -> Result<u64> {
        let n = if self.snapshot_due(round, t) {
            let f = self.frame_corrupted(round, payload);
            let tmp = self.dir.join("snapshot.tmp");
            let snap = self.dir.join(SNAPSHOT);
            fs::write(&tmp, &f).with_context(|| {
                format!("writing {}", tmp.display())
            })?;
            if snap.exists() {
                fs::rename(&snap, self.dir.join(SNAPSHOT_PREV))
                    .context("rotating previous snapshot")?;
            }
            fs::rename(&tmp, &snap).context("installing snapshot")?;
            // the journal's records are all older than the snapshot now
            fs::write(self.dir.join(JOURNAL), [])
                .context("truncating journal")?;
            self.last_snapshot_round = Some(round);
            self.last_snapshot_t = t;
            f.len() as u64
        } else {
            let f = self.frame_corrupted(round, payload);
            let mut file = fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(self.dir.join(JOURNAL))
                .context("opening journal")?;
            file.write_all(&f).context("appending journal record")?;
            f.len() as u64
        };
        self.written += 1;
        self.bytes += n;
        Ok(n)
    }
}

// ---------------------------------------------------------------------------
// recovery
// ---------------------------------------------------------------------------

/// The state chosen by [`recover`]: the newest checksum-valid record.
pub struct Recovered {
    pub round: u64,
    pub payload: Vec<u8>,
    /// Corrupt newer candidates skipped to reach this record (torn writes
    /// + bit flips) — surfaced as `Report::checkpoint_fallbacks`.
    pub fallbacks: u64,
}

/// Pick the newest valid record: journal tail first (those are newer than
/// any snapshot — the journal is truncated when a snapshot lands), then
/// `snapshot.bin`, then `snapshot.prev.bin`.  Every corrupt candidate
/// newer than the chosen one counts as a fallback.
pub fn recover(dir: &Path) -> Result<Recovered> {
    let mut fallbacks = 0u64;
    let journal = scan_file(&dir.join(JOURNAL))?;
    fallbacks += journal.bad;
    if let Some(rec) = journal.records.into_iter().last() {
        return Ok(Recovered {
            round: rec.round,
            payload: rec.payload,
            fallbacks,
        });
    }
    for name in [SNAPSHOT, SNAPSHOT_PREV] {
        let snap = scan_file(&dir.join(name))?;
        fallbacks += snap.bad;
        if let Some(rec) = snap.records.into_iter().last() {
            return Ok(Recovered {
                round: rec.round,
                payload: rec.payload,
                fallbacks,
            });
        }
    }
    bail!(
        "no valid checkpoint record in {} ({} corrupt candidate(s))",
        dir.display(),
        fallbacks
    )
}

// ---------------------------------------------------------------------------
// crash injection
// ---------------------------------------------------------------------------

/// Salt for the dedicated crash-decision stream (never collides with the
/// backend fault stream or any data stream).
const CRASH_SEED_SALT: u64 = 0xC4A5_0FF1_CE5A_17ED;

/// Typed error returned by `Simulation::run` when a crash point fires;
/// the CLI downcasts it to map onto a distinct exit code.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CrashInjected {
    pub round: u64,
    pub t: f64,
}

impl fmt::Display for CrashInjected {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "injected crash at round {} (t={:.3}s); resume with \
             --resume <checkpoint-dir>",
            self.round, self.t
        )
    }
}

impl std::error::Error for CrashInjected {}

/// Crash-point evaluator, consulted by the simulation at every round
/// boundary.  One-shot points (`after-round-N`, `t=S`) latch after
/// firing; the latches and the rate stream's RNG are part of the
/// checkpoint payload — written *post-draw*, so a resumed run never
/// re-fires the crash that killed it.
#[derive(Clone, Debug)]
pub struct CrashState {
    after_round: u64,
    t_at: f64,
    rate: f64,
    rng: Pcg32,
    round_fired: bool,
    t_fired: bool,
}

impl CrashState {
    pub fn new(plan: &FaultPlan, run_seed: u64) -> CrashState {
        let seed = run_seed
            ^ plan.seed.wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ CRASH_SEED_SALT;
        CrashState {
            after_round: plan.crash_after_round,
            t_at: plan.crash_t,
            rate: plan.crash_rate,
            rng: Pcg32::new(seed, 0xC4A5),
            round_fired: false,
            t_fired: false,
        }
    }

    pub fn enabled(&self) -> bool {
        self.after_round > 0 || self.t_at >= 0.0 || self.rate > 0.0
    }

    /// Decide at one round boundary.  Consumes the one-shot latches and
    /// advances the rate stream; call exactly once per boundary, *before*
    /// serializing this state into the boundary's record.
    pub fn check(&mut self, round: u64, t: f64) -> bool {
        let mut fire = false;
        if self.after_round > 0 && !self.round_fired && round >= self.after_round
        {
            self.round_fired = true;
            fire = true;
        }
        if self.t_at >= 0.0 && !self.t_fired && t >= self.t_at {
            self.t_fired = true;
            fire = true;
        }
        if self.rate > 0.0 && self.rng.f64() < self.rate {
            fire = true;
        }
        fire
    }

    pub fn save(&self, w: &mut ByteWriter) {
        w.bool(self.round_fired);
        w.bool(self.t_fired);
        let (s, i) = self.rng.state();
        w.u64(s);
        w.u64(i);
    }

    pub fn load(&mut self, r: &mut ByteReader) -> Result<()> {
        self.round_fired = r.bool()?;
        self.t_fired = r.bool()?;
        let s = r.u64()?;
        let i = r.u64()?;
        self.rng = Pcg32::from_state(s, i);
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// config digest
// ---------------------------------------------------------------------------

/// Stable digest of a run's *scientific* configuration: keys sweep-journal
/// cells and validates that `--resume` repeats the original flags.  The
/// checkpoint knobs themselves are neutralized first — where the state is
/// persisted must not change what run it belongs to.  Everything else
/// (model, benchmark, policies, seed, arrivals, device, serve/fleet
/// knobs, fault spec) participates via the config's `Debug` rendering,
/// which round-trips floats exactly.
pub fn config_digest(cfg: &crate::sim::RunConfig) -> u64 {
    let mut c = cfg.clone();
    c.checkpoint = CheckpointConfig::default();
    fnv64(format!("{c:?}").as_bytes())
}

// ---------------------------------------------------------------------------
// sweep journal
// ---------------------------------------------------------------------------

/// Append-only journal of completed sweep cells: one framed record per
/// cell, keyed by [`config_digest`] (stored in the frame's round slot)
/// with the cell's full [`Report`] as payload.  `ParallelSweeper` resumes
/// a grid by skipping cells whose digest already has a valid record.
pub struct SweepJournal {
    path: PathBuf,
}

impl SweepJournal {
    pub fn new(path: &Path) -> SweepJournal {
        SweepJournal { path: path.to_path_buf() }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Digest → report for every valid record (later records win, so a
    /// re-run cell overrides).  Corrupt/torn records are simply skipped —
    /// their cells re-run.
    pub fn load(&self) -> Result<Vec<(u64, Report)>> {
        let scan = scan_file(&self.path)?;
        let mut out: Vec<(u64, Report)> = Vec::new();
        for rec in scan.records {
            if let Ok(report) = report_load_bytes(&rec.payload) {
                out.retain(|(d, _)| *d != rec.round);
                out.push((rec.round, report));
            }
        }
        Ok(out)
    }

    /// Append one completed cell.
    pub fn record(&self, digest: u64, report: &Report) -> Result<()> {
        if let Some(parent) = self.path.parent() {
            if !parent.as_os_str().is_empty() {
                fs::create_dir_all(parent)?;
            }
        }
        let mut w = ByteWriter::new();
        report_save(report, &mut w);
        let f = frame(digest, &w.into_vec());
        let mut file = fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&self.path)
            .with_context(|| {
                format!("opening sweep journal {}", self.path.display())
            })?;
        file.write_all(&f).context("appending sweep journal record")?;
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// Report codec
// ---------------------------------------------------------------------------

/// Serialize an in-progress or finished [`Report`] bit-exactly.  The
/// destructuring has NO `..` rest pattern on purpose: adding a `Report`
/// field fails to compile here until the codec handles it — the same
/// census discipline `report_field_census_is_exhaustive` enforces for the
/// fingerprint.
pub fn report_save(r: &Report, w: &mut ByteWriter) {
    #[rustfmt::skip]
    let Report {
        model, benchmark, tune_policy, freeze_policy, seed,
        avg_inference_accuracy, energy, rounds, train_iterations,
        train_tflops, cka_tflops, scenario_changes_detected, requests,
        round_log, memory_begin_bytes, memory_end_bytes, wall_exec_s,
        cka_trace, theta_marshals, theta_cache_hits, serving_rebuilds,
        serving_hits, gemm_packs, gemm_pack_hits, scratch_allocs,
        scratch_reuses, scratch_bytes_reused, latency_p50_ms,
        latency_p95_ms, latency_p99_ms, latency_mean_ms, latency_max_ms,
        slo_ms, slo_violations, serve_executes, avg_batch_requests,
        peak_queue_depth, rounds_deferred, queue_policy, requests_dropped,
        drops_queue_full, drops_slo_infeasible, deadline_misses,
        bank_evictions, banks_peak_resident, per_scenario_latency,
        faults_injected_exec, faults_injected_marshal,
        faults_injected_spikes, fault_delay_injected_s, serve_retries,
        serve_flush_failures, breaker_trips, degraded_serves,
        drops_backend_unavailable, round_rollbacks, fleet_engines,
        fleet_routed_affinity, fleet_routed_least_loaded,
        fleet_cross_engine_retries, fleet_rebalances, checkpoints_written,
        checkpoint_bytes, checkpoint_restores, checkpoint_fallbacks,
        time_serving_s, time_tuning_s, time_idle_s, hists,
    } = r;
    w.str(model);
    w.str(benchmark);
    w.str(tune_policy);
    w.str(freeze_policy);
    w.u64(*seed);
    w.f64(*avg_inference_accuracy);
    w.f64(energy.init_s);
    w.f64(energy.loadsave_s);
    w.f64(energy.compute_s);
    w.f64(energy.init_j);
    w.f64(energy.loadsave_j);
    w.f64(energy.compute_j);
    w.u64(*rounds);
    w.u64(*train_iterations);
    w.f64(*train_tflops);
    w.f64(*cka_tflops);
    w.u64(*scenario_changes_detected);
    w.usize(requests.len());
    for q in requests {
        let RequestRecord {
            t,
            scenario,
            accuracy,
            stale_batches,
            latency_s,
            batch_requests,
            queue_depth,
            degraded,
        } = q;
        w.f64(*t);
        w.usize(*scenario);
        w.f32(*accuracy);
        w.usize(*stale_batches);
        w.f64(*latency_s);
        w.usize(*batch_requests);
        w.usize(*queue_depth);
        w.bool(*degraded);
    }
    w.usize(round_log.len());
    for q in round_log {
        let RoundRecord {
            t,
            scenario,
            batches,
            iterations,
            batches_needed,
            val_acc,
            frozen_units,
        } = q;
        w.f64(*t);
        w.usize(*scenario);
        w.usize(*batches);
        w.u64(*iterations);
        w.usize(*batches_needed);
        w.f64(*val_acc);
        w.usize(*frozen_units);
    }
    w.f64(*memory_begin_bytes);
    w.f64(*memory_end_bytes);
    w.f64(*wall_exec_s);
    w.usize(cka_trace.len());
    for s in cka_trace {
        w.u64(s.iteration);
        w.usize(s.layer);
        w.f32(s.cka);
    }
    w.u64(*theta_marshals);
    w.u64(*theta_cache_hits);
    w.u64(*serving_rebuilds);
    w.u64(*serving_hits);
    w.u64(*gemm_packs);
    w.u64(*gemm_pack_hits);
    w.u64(*scratch_allocs);
    w.u64(*scratch_reuses);
    w.u64(*scratch_bytes_reused);
    w.f64(*latency_p50_ms);
    w.f64(*latency_p95_ms);
    w.f64(*latency_p99_ms);
    w.f64(*latency_mean_ms);
    w.f64(*latency_max_ms);
    w.f64(*slo_ms);
    w.u64(*slo_violations);
    w.u64(*serve_executes);
    w.f64(*avg_batch_requests);
    w.u64(*peak_queue_depth);
    w.u64(*rounds_deferred);
    w.str(queue_policy);
    w.u64(*requests_dropped);
    w.u64(*drops_queue_full);
    w.u64(*drops_slo_infeasible);
    w.u64(*deadline_misses);
    w.u64(*bank_evictions);
    w.u64(*banks_peak_resident);
    w.usize(per_scenario_latency.len());
    for s in per_scenario_latency {
        let ScenarioLatency {
            scenario,
            requests,
            mean_ms,
            p95_ms,
            max_ms,
            deadline_misses,
        } = s;
        w.usize(*scenario);
        w.u64(*requests);
        w.f64(*mean_ms);
        w.f64(*p95_ms);
        w.f64(*max_ms);
        w.u64(*deadline_misses);
    }
    w.u64(*faults_injected_exec);
    w.u64(*faults_injected_marshal);
    w.u64(*faults_injected_spikes);
    w.f64(*fault_delay_injected_s);
    w.u64(*serve_retries);
    w.u64(*serve_flush_failures);
    w.u64(*breaker_trips);
    w.u64(*degraded_serves);
    w.u64(*drops_backend_unavailable);
    w.u64(*round_rollbacks);
    w.u64(*fleet_engines);
    w.u64(*fleet_routed_affinity);
    w.u64(*fleet_routed_least_loaded);
    w.u64(*fleet_cross_engine_retries);
    w.u64(*fleet_rebalances);
    w.u64(*checkpoints_written);
    w.u64(*checkpoint_bytes);
    w.u64(*checkpoint_restores);
    w.u64(*checkpoint_fallbacks);
    w.f64(*time_serving_s);
    w.f64(*time_tuning_s);
    w.f64(*time_idle_s);
    // histograms: persist the exact samples per key; re-recording them in
    // order rebuilds identical buckets and max by construction.
    let keys: Vec<&str> = hists.keys().collect();
    w.usize(keys.len());
    for k in keys {
        w.str(k);
        w.f64s(hists.get(k).map(|h| h.samples()).unwrap_or(&[]));
    }
}

/// Inverse of [`report_save`].
pub fn report_load(r: &mut ByteReader) -> Result<Report> {
    let mut out = Report::default();
    out.model = r.str()?;
    out.benchmark = r.str()?;
    out.tune_policy = r.str()?;
    out.freeze_policy = r.str()?;
    out.seed = r.u64()?;
    out.avg_inference_accuracy = r.f64()?;
    out.energy.init_s = r.f64()?;
    out.energy.loadsave_s = r.f64()?;
    out.energy.compute_s = r.f64()?;
    out.energy.init_j = r.f64()?;
    out.energy.loadsave_j = r.f64()?;
    out.energy.compute_j = r.f64()?;
    out.rounds = r.u64()?;
    out.train_iterations = r.u64()?;
    out.train_tflops = r.f64()?;
    out.cka_tflops = r.f64()?;
    out.scenario_changes_detected = r.u64()?;
    let n = r.usize()?;
    out.requests = (0..n)
        .map(|_| -> Result<RequestRecord> {
            Ok(RequestRecord {
                t: r.f64()?,
                scenario: r.usize()?,
                accuracy: r.f32()?,
                stale_batches: r.usize()?,
                latency_s: r.f64()?,
                batch_requests: r.usize()?,
                queue_depth: r.usize()?,
                degraded: r.bool()?,
            })
        })
        .collect::<Result<_>>()?;
    let n = r.usize()?;
    out.round_log = (0..n)
        .map(|_| -> Result<RoundRecord> {
            Ok(RoundRecord {
                t: r.f64()?,
                scenario: r.usize()?,
                batches: r.usize()?,
                iterations: r.u64()?,
                batches_needed: r.usize()?,
                val_acc: r.f64()?,
                frozen_units: r.usize()?,
            })
        })
        .collect::<Result<_>>()?;
    out.memory_begin_bytes = r.f64()?;
    out.memory_end_bytes = r.f64()?;
    out.wall_exec_s = r.f64()?;
    let n = r.usize()?;
    out.cka_trace = (0..n)
        .map(|_| -> Result<crate::coordinator::simfreeze::CkaSample> {
            Ok(crate::coordinator::simfreeze::CkaSample {
                iteration: r.u64()?,
                layer: r.usize()?,
                cka: r.f32()?,
            })
        })
        .collect::<Result<_>>()?;
    out.theta_marshals = r.u64()?;
    out.theta_cache_hits = r.u64()?;
    out.serving_rebuilds = r.u64()?;
    out.serving_hits = r.u64()?;
    out.gemm_packs = r.u64()?;
    out.gemm_pack_hits = r.u64()?;
    out.scratch_allocs = r.u64()?;
    out.scratch_reuses = r.u64()?;
    out.scratch_bytes_reused = r.u64()?;
    out.latency_p50_ms = r.f64()?;
    out.latency_p95_ms = r.f64()?;
    out.latency_p99_ms = r.f64()?;
    out.latency_mean_ms = r.f64()?;
    out.latency_max_ms = r.f64()?;
    out.slo_ms = r.f64()?;
    out.slo_violations = r.u64()?;
    out.serve_executes = r.u64()?;
    out.avg_batch_requests = r.f64()?;
    out.peak_queue_depth = r.u64()?;
    out.rounds_deferred = r.u64()?;
    out.queue_policy = r.str()?;
    out.requests_dropped = r.u64()?;
    out.drops_queue_full = r.u64()?;
    out.drops_slo_infeasible = r.u64()?;
    out.deadline_misses = r.u64()?;
    out.bank_evictions = r.u64()?;
    out.banks_peak_resident = r.u64()?;
    let n = r.usize()?;
    out.per_scenario_latency = (0..n)
        .map(|_| -> Result<ScenarioLatency> {
            Ok(ScenarioLatency {
                scenario: r.usize()?,
                requests: r.u64()?,
                mean_ms: r.f64()?,
                p95_ms: r.f64()?,
                max_ms: r.f64()?,
                deadline_misses: r.u64()?,
            })
        })
        .collect::<Result<_>>()?;
    out.faults_injected_exec = r.u64()?;
    out.faults_injected_marshal = r.u64()?;
    out.faults_injected_spikes = r.u64()?;
    out.fault_delay_injected_s = r.f64()?;
    out.serve_retries = r.u64()?;
    out.serve_flush_failures = r.u64()?;
    out.breaker_trips = r.u64()?;
    out.degraded_serves = r.u64()?;
    out.drops_backend_unavailable = r.u64()?;
    out.round_rollbacks = r.u64()?;
    out.fleet_engines = r.u64()?;
    out.fleet_routed_affinity = r.u64()?;
    out.fleet_routed_least_loaded = r.u64()?;
    out.fleet_cross_engine_retries = r.u64()?;
    out.fleet_rebalances = r.u64()?;
    out.checkpoints_written = r.u64()?;
    out.checkpoint_bytes = r.u64()?;
    out.checkpoint_restores = r.u64()?;
    out.checkpoint_fallbacks = r.u64()?;
    out.time_serving_s = r.f64()?;
    out.time_tuning_s = r.f64()?;
    out.time_idle_s = r.f64()?;
    let n = r.usize()?;
    let mut hists = HistRegistry::new();
    for _ in 0..n {
        let key = r.str()?;
        let samples = r.f64s()?;
        let mut h = Histogram::new();
        for v in samples {
            h.record(v);
        }
        hists.insert(&key, h);
    }
    out.hists = hists;
    Ok(out)
}

/// [`report_load`] over a standalone payload (must consume every byte).
pub fn report_load_bytes(bytes: &[u8]) -> Result<Report> {
    let mut r = ByteReader::new(bytes);
    let report = report_load(&mut r)?;
    r.expect_end()?;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicU64, Ordering};

    /// Unique scratch dir per test (no Date/rand in tests either — a
    /// process-local counter is enough).
    fn scratch(name: &str) -> PathBuf {
        static N: AtomicU64 = AtomicU64::new(0);
        let dir = std::env::temp_dir().join(format!(
            "etuner-ckpt-{}-{}-{}",
            std::process::id(),
            name,
            N.fetch_add(1, Ordering::Relaxed)
        ));
        let _ = fs::remove_dir_all(&dir);
        fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn byte_codec_round_trips_every_type() {
        let mut w = ByteWriter::new();
        w.u8(7);
        w.bool(true);
        w.u32(0xDEAD_BEEF);
        w.i32(-42);
        w.u64(u64::MAX - 1);
        w.usize(12345);
        w.f32(-0.0);
        w.f64(f64::MIN_POSITIVE);
        w.str("hällo");
        w.bytes(&[1, 2, 3]);
        w.f32s(&[1.5, -2.5]);
        w.f64s(&[0.1]);
        w.i32s(&[-1, 0, 1]);
        w.u32s(&[9]);
        w.bools(&[true, false]);
        w.opt_f64(Some(3.25));
        w.opt_f64(None);
        w.opt_usize(Some(0));
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf);
        assert_eq!(r.u8().unwrap(), 7);
        assert!(r.bool().unwrap());
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.i32().unwrap(), -42);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.usize().unwrap(), 12345);
        assert_eq!(r.f32().unwrap().to_bits(), (-0.0f32).to_bits());
        assert_eq!(r.f64().unwrap(), f64::MIN_POSITIVE);
        assert_eq!(r.str().unwrap(), "hällo");
        assert_eq!(r.bytes().unwrap(), vec![1, 2, 3]);
        assert_eq!(r.f32s().unwrap(), vec![1.5, -2.5]);
        assert_eq!(r.f64s().unwrap(), vec![0.1]);
        assert_eq!(r.i32s().unwrap(), vec![-1, 0, 1]);
        assert_eq!(r.u32s().unwrap(), vec![9]);
        assert_eq!(r.bools().unwrap(), vec![true, false]);
        assert_eq!(r.opt_f64().unwrap(), Some(3.25));
        assert_eq!(r.opt_f64().unwrap(), None);
        assert_eq!(r.opt_usize().unwrap(), Some(0));
        r.expect_end().unwrap();
    }

    #[test]
    fn truncated_payload_errors_instead_of_panicking() {
        let mut w = ByteWriter::new();
        w.u64(5);
        let buf = w.into_vec();
        let mut r = ByteReader::new(&buf[..4]);
        assert!(r.u64().is_err());
        let mut r = ByteReader::new(&buf);
        r.u64().unwrap();
        assert!(r.u8().is_err(), "reading past the end errors");
    }

    #[test]
    fn frames_scan_back_in_order() {
        let mut file = Vec::new();
        file.extend_from_slice(&frame(1, b"one"));
        file.extend_from_slice(&frame(2, b"two"));
        file.extend_from_slice(&frame(3, b"three"));
        let out = scan(&file);
        assert_eq!(out.bad, 0);
        assert_eq!(out.records.len(), 3);
        assert_eq!(out.records[2].round, 3);
        assert_eq!(out.records[2].payload, b"three");
    }

    #[test]
    fn bit_flip_skips_one_record_torn_tail_stops() {
        let mut file = Vec::new();
        file.extend_from_slice(&frame(1, b"good-1"));
        let mut bad = frame(2, b"flipped");
        bad[HEADER_LEN + 2] ^= 0x01;
        file.extend_from_slice(&bad);
        file.extend_from_slice(&frame(3, b"good-3"));
        // torn tail: half a frame
        let torn = frame(4, b"torn-record");
        file.extend_from_slice(&torn[..torn.len() / 2]);
        let out = scan(&file);
        assert_eq!(out.records.len(), 2, "flip skipped, tail dropped");
        assert_eq!(out.records[0].round, 1);
        assert_eq!(out.records[1].round, 3);
        assert_eq!(out.bad, 2);
    }

    #[test]
    fn cadence_grammar() {
        assert_eq!(Cadence::parse("3r").unwrap(), Cadence::Rounds(3));
        assert_eq!(Cadence::parse("120s").unwrap(), Cadence::Seconds(120.0));
        assert_eq!(Cadence::parse(" 1R ").unwrap(), Cadence::Rounds(1));
        assert!(Cadence::parse("0r").is_err());
        assert!(Cadence::parse("-5s").is_err());
        assert!(Cadence::parse("7").is_err());
        assert!(Cadence::parse("xr").is_err());
        assert_eq!(Cadence::parse("3r").unwrap().to_string(), "3r");
        assert_eq!(Cadence::default(), Cadence::Rounds(1));
    }

    #[test]
    fn writer_rotates_snapshots_and_journals_between() {
        let dir = scratch("rotate");
        let plan = FaultPlan::none();
        let mut w =
            CheckpointWriter::new(&dir, Cadence::Rounds(2), &plan).unwrap();
        w.on_boundary(1, 10.0, b"state-1").unwrap(); // first: snapshot
        w.on_boundary(2, 20.0, b"state-2").unwrap(); // off-cadence: journal
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.round, 2, "journal record is newest");
        assert_eq!(rec.payload, b"state-2");
        assert_eq!(rec.fallbacks, 0);
        w.on_boundary(3, 30.0, b"state-3").unwrap(); // cadence: snapshot
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.round, 3, "snapshot supersedes truncated journal");
        // prev snapshot holds round 1
        let prev = scan(&fs::read(dir.join(SNAPSHOT_PREV)).unwrap());
        assert_eq!(prev.records[0].round, 1);
        assert_eq!(w.written, 3);
        assert!(w.bytes > 0);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn corrupt_newest_falls_back_with_count() {
        let dir = scratch("fallback");
        // flip the 2nd record: with 1r cadence that's the round-2 snapshot
        let plan = FaultPlan::parse("ckpt-flip:2").unwrap();
        let mut w =
            CheckpointWriter::new(&dir, Cadence::Rounds(1), &plan).unwrap();
        w.on_boundary(1, 10.0, b"state-1").unwrap();
        w.on_boundary(2, 20.0, b"state-2").unwrap(); // corrupted snapshot
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.round, 1, "fell back to the previous snapshot");
        assert_eq!(rec.payload, b"state-1");
        assert_eq!(rec.fallbacks, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn torn_snapshot_falls_back_too() {
        let dir = scratch("torn");
        let plan = FaultPlan::parse("ckpt-torn:3").unwrap();
        let mut w =
            CheckpointWriter::new(&dir, Cadence::Rounds(1), &plan).unwrap();
        w.on_boundary(1, 1.0, b"aaaa").unwrap();
        w.on_boundary(2, 2.0, b"bbbb").unwrap();
        w.on_boundary(3, 3.0, b"cccc").unwrap(); // torn write
        let rec = recover(&dir).unwrap();
        assert_eq!(rec.round, 2);
        assert_eq!(rec.fallbacks, 1);
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn recover_errors_when_nothing_valid() {
        let dir = scratch("empty");
        assert!(recover(&dir).is_err());
        fs::remove_dir_all(&dir).unwrap();
    }

    #[test]
    fn crash_state_latches_and_round_trips() {
        let plan = FaultPlan::parse("crash:after-round-2").unwrap();
        let mut cs = CrashState::new(&plan, 7);
        assert!(cs.enabled());
        assert!(!cs.check(1, 10.0));
        assert!(cs.check(2, 20.0), "fires at its round");
        // latch consumed: saved state must not re-fire after resume
        let mut w = ByteWriter::new();
        cs.save(&mut w);
        let buf = w.into_vec();
        let mut fresh = CrashState::new(&plan, 7);
        let mut r = ByteReader::new(&buf);
        fresh.load(&mut r).unwrap();
        assert!(!fresh.check(2, 20.0), "restored latch suppresses re-fire");
        assert!(!fresh.check(3, 30.0));
    }

    #[test]
    fn crash_rate_stream_is_deterministic_across_save() {
        let plan = FaultPlan::parse("crash:0.5,seed:3").unwrap();
        let mut a = CrashState::new(&plan, 11);
        let mut b = CrashState::new(&plan, 11);
        let seq_a: Vec<bool> =
            (1..=32).map(|i| a.check(i, i as f64)).collect();
        // b: draw half, save, restore into a fresh state, draw the rest
        let head: Vec<bool> = (1..=16).map(|i| b.check(i, i as f64)).collect();
        let mut w = ByteWriter::new();
        b.save(&mut w);
        let buf = w.into_vec();
        let mut c = CrashState::new(&plan, 999); // wrong seed on purpose
        let mut r = ByteReader::new(&buf);
        c.load(&mut r).unwrap();
        let tail: Vec<bool> =
            (17..=32).map(|i| c.check(i, i as f64)).collect();
        let mut joined = head;
        joined.extend(tail);
        assert_eq!(joined, seq_a, "restored rate stream continues exactly");
        assert!(seq_a.iter().any(|&f| f), "rate 0.5 fires somewhere in 32");
    }

    #[test]
    fn report_codec_round_trips_bit_exactly() {
        let mut rep = Report::default();
        rep.model = "mbv2".into();
        rep.benchmark = "scifar10".into();
        rep.tune_policy = "LazyTune".into();
        rep.freeze_policy = "SimFreeze".into();
        rep.seed = 42;
        rep.energy.compute_j = 123.456789;
        rep.energy.init_s = 0.125;
        rep.rounds = 9;
        rep.train_iterations = 77;
        rep.train_tflops = 1.5e-3;
        rep.scenario_changes_detected = 2;
        rep.requests.push(RequestRecord {
            t: 12.5,
            scenario: 1,
            accuracy: 0.625,
            stale_batches: 3,
            latency_s: 0.03125,
            batch_requests: 2,
            queue_depth: 1,
            degraded: true,
        });
        rep.round_log.push(RoundRecord {
            t: 10.0,
            scenario: 0,
            batches: 4,
            iterations: 4,
            batches_needed: 2,
            val_acc: 0.875,
            frozen_units: 1,
        });
        rep.cka_trace.push(crate::coordinator::simfreeze::CkaSample {
            iteration: 8,
            layer: 2,
            cka: 0.99,
        });
        rep.per_scenario_latency.push(ScenarioLatency {
            scenario: 0,
            requests: 5,
            mean_ms: 2.0,
            p95_ms: 4.0,
            max_ms: 8.0,
            deadline_misses: 1,
        });
        rep.queue_policy = "edf".into();
        rep.memory_begin_bytes = 1e6;
        rep.memory_end_bytes = 9e5;
        rep.checkpoints_written = 3;
        rep.checkpoint_bytes = 4096;
        rep.hists.record("serve/latency_ms", 1.25);
        rep.hists.record("serve/latency_ms", 2.5);
        rep.hists.record("tune/round_s", 7.0);
        rep.finish();
        let mut w = ByteWriter::new();
        report_save(&rep, &mut w);
        let buf = w.into_vec();
        let back = report_load_bytes(&buf).unwrap();
        assert_eq!(rep.fingerprint(), back.fingerprint());
        assert_eq!(back.queue_policy, "edf");
        assert_eq!(back.checkpoints_written, 3);
        assert_eq!(back.per_scenario_latency, rep.per_scenario_latency);
        assert_eq!(back.hists, rep.hists, "histograms rebuild identically");
        assert_eq!(
            back.requests[0].latency_s.to_bits(),
            rep.requests[0].latency_s.to_bits()
        );
        assert!(back.requests[0].degraded);
    }

    #[test]
    fn sweep_journal_records_and_skips_corrupt() {
        let dir = scratch("sweepj");
        let j = SweepJournal::new(&dir.join("cells.bin"));
        let mut a = Report::default();
        a.seed = 1;
        a.rounds = 3;
        let mut b = Report::default();
        b.seed = 2;
        b.rounds = 5;
        j.record(100, &a).unwrap();
        j.record(200, &b).unwrap();
        let cells = j.load().unwrap();
        assert_eq!(cells.len(), 2);
        let get = |d: u64| {
            cells.iter().find(|(k, _)| *k == d).map(|(_, r)| r).unwrap()
        };
        assert_eq!(get(100).rounds, 3);
        assert_eq!(get(200).fingerprint(), b.fingerprint());
        // corrupt the tail: load still returns the intact records
        let mut raw = fs::read(j.path()).unwrap();
        let cut = raw.len() - 5;
        raw.truncate(cut);
        raw.extend_from_slice(&[0xFF; 3]);
        fs::write(j.path(), &raw).unwrap();
        let cells = j.load().unwrap();
        assert_eq!(cells.len(), 1, "only the intact record survives");
        assert_eq!(cells[0].0, 100);
        fs::remove_dir_all(&dir).unwrap();
    }
}
