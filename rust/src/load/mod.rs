//! # Open-loop workload generation + capacity search
//!
//! Everything before this module drove the stack with a single
//! closed-ish synthetic trace: [`crate::data::arrival`] always rescales
//! its gaps to span the horizon, so the offered rate is pinned at
//! `n_requests / horizon` and the system can never be pushed past
//! saturation.  This subsystem asks the production questions the
//! ROADMAP north-star names — *what is the max sustainable RPS under
//! the SLO?  what happens at the diurnal peak when a tuning round
//! lands?* — in three layers:
//!
//! * [`gen`] — seeded, deterministic **open-loop** generators
//!   (Poisson / bursty on-off / diurnal envelope / heavy-tailed
//!   Pareto): timestamps at a configured offered rate, independent of
//!   completions, so queues genuinely grow (`--workload`,
//!   `--offered-rps`);
//! * [`mix`] — Zipf-skewed multi-scenario composition with an optional
//!   mid-run popularity shift (`--mix zipf:s=1.1,k=8,shift=0.5`) to
//!   stress [`crate::serve::BankSet`] eviction and
//!   [`crate::serve::FleetRouter`] affinity;
//! * [`capacity`] — the capacity-search driver (`etuner capacity`,
//!   `repro capacity`): bisects offered RPS for the knee of the
//!   latency-vs-throughput curve against an SLO predicate, running each
//!   fixed fan-out of probe points through
//!   [`crate::sim::ParallelSweeper`] — concurrent probes, sequential
//!   bit-identity.
//!
//! **Determinism contract:** generation draws from one dedicated
//! [`crate::rng::Pcg32`] stream salted off the run seed; with
//! `workload: None` (the default) the closed stream's RNG sequence and
//! reports stay byte-identical to every prior PR.  The per-probe
//! observability (request interarrival histogram, latency/queue hists,
//! traces) rides the existing fingerprint-excluded channels.

pub mod capacity;
pub mod gen;
pub mod mix;

pub use capacity::{
    capacity_search, CapacityProbe, CapacityResult, CapacitySpec,
};
pub use gen::{open_loop_times, WorkloadKind, WorkloadSpec};
pub use mix::{MixSampler, MixSpec};
