//! Multi-scenario workload composition: Zipf-skewed scenario popularity
//! with an optional mid-run popularity shift.
//!
//! Real edge deployments don't spread requests evenly over scenarios —
//! a few are hot and the tail is cold.  `--mix zipf:s=1.1,k=8` draws
//! each request's scenario from a Zipf(s) distribution over the top `k`
//! popularity ranks (rank `r` gets weight `1/(r+1)^s`), mapped onto the
//! benchmark's continual scenarios `1..n_scen`.  The optional
//! `shift=<frac>` term rotates the rank→scenario mapping once `t`
//! crosses `frac × horizon` — the paper's "deployment scenario change",
//! which stresses [`crate::serve::BankSet`] eviction (the hot bank
//! changes identity) and [`crate::serve::FleetRouter`] affinity (the
//! hot engine moves).

use anyhow::{anyhow, bail, ensure, Result};

use crate::rng::Pcg32;

/// Parsed `--mix` grammar: `zipf[:s=<skew>,k=<ranks>,shift=<frac>]`.
#[derive(Clone, Debug, PartialEq)]
pub struct MixSpec {
    /// Zipf skew exponent `s` (0 = uniform over the top `k`).
    pub skew: f64,
    /// Popularity ranks to draw from (clamped to the benchmark's
    /// continual scenario count at sampling time).
    pub ranks: usize,
    /// Rotate the rank→scenario mapping at `frac × horizon` (`None` =
    /// popularity is stationary).
    pub shift_frac: Option<f64>,
}

impl Default for MixSpec {
    fn default() -> MixSpec {
        MixSpec { skew: 1.1, ranks: 8, shift_frac: None }
    }
}

impl MixSpec {
    /// Parse the CLI grammar.  `zipf` alone takes every default;
    /// `zipf:s=1.2,k=4,shift=0.5` overrides per key.
    pub fn parse(spec: &str) -> Result<MixSpec> {
        let rest = spec.strip_prefix("zipf").ok_or_else(|| {
            anyhow!(
                "unknown mix '{spec}' \
                 (grammar: zipf[:s=<skew>,k=<ranks>,shift=<frac>])"
            )
        })?;
        let mut m = MixSpec::default();
        let rest = match rest {
            "" => return Ok(m),
            r => r.strip_prefix(':').ok_or_else(|| {
                anyhow!("unknown mix '{spec}' (expected 'zipf:' prefix)")
            })?,
        };
        for part in rest.split(',') {
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("bad mix term '{part}' (want k=v)"))?;
            match key {
                "s" => {
                    m.skew = val
                        .parse()
                        .map_err(|_| anyhow!("bad mix skew '{val}'"))?;
                }
                "k" => {
                    m.ranks = val
                        .parse()
                        .map_err(|_| anyhow!("bad mix ranks '{val}'"))?;
                }
                "shift" => {
                    m.shift_frac = Some(
                        val.parse()
                            .map_err(|_| anyhow!("bad mix shift '{val}'"))?,
                    );
                }
                other => bail!("unknown mix key '{other}' (s, k, shift)"),
            }
        }
        ensure!(m.skew >= 0.0, "mix skew must be >= 0, got {}", m.skew);
        ensure!(m.ranks >= 1, "mix needs at least one rank");
        if let Some(f) = m.shift_frac {
            ensure!(
                (0.0..=1.0).contains(&f),
                "mix shift must be a fraction in [0, 1], got {f}"
            );
        }
        Ok(m)
    }

    /// Canonical display form (CLI help, repro table labels).
    pub fn label(&self) -> String {
        match self.shift_frac {
            Some(f) => {
                format!("zipf:s={},k={},shift={}", self.skew, self.ranks, f)
            }
            None => format!("zipf:s={},k={}", self.skew, self.ranks),
        }
    }
}

/// A [`MixSpec`] bound to a benchmark: precomputed Zipf CDF over the
/// clamped rank set, plus the shift point in virtual seconds.
#[derive(Clone, Debug)]
pub struct MixSampler {
    /// Cumulative normalized rank weights, ascending.
    cdf: Vec<f64>,
    /// Continual scenarios (`n_scen - 1`; scenario 0 never serves).
    scenarios: usize,
    /// Rotate the rank→scenario map for arrivals at or past this time.
    shift_t: Option<f64>,
    /// Rotation distance (half the scenario ring, ≥ 1): the hot rank
    /// lands on a scenario that was cold before the shift.
    rot: usize,
}

impl MixSampler {
    pub fn new(spec: &MixSpec, n_scen: usize, horizon: f64) -> MixSampler {
        let scenarios = n_scen.saturating_sub(1).max(1);
        let ranks = spec.ranks.clamp(1, scenarios);
        let weights: Vec<f64> = (0..ranks)
            .map(|r| 1.0 / ((r + 1) as f64).powf(spec.skew))
            .collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        MixSampler {
            cdf,
            scenarios,
            shift_t: spec.shift_frac.map(|f| f * horizon),
            rot: (scenarios / 2).max(1),
        }
    }

    /// Draw the scenario for an arrival at time `t`.  Always in
    /// `1..=scenarios` — a valid index into the benchmark schedule.
    pub fn scenario_at(&self, t: f64, rng: &mut Pcg32) -> usize {
        let u = rng.f64();
        let rank = self
            .cdf
            .iter()
            .position(|&c| u < c)
            .unwrap_or(self.cdf.len() - 1);
        let slot = match self.shift_t {
            Some(st) if t >= st => (rank + self.rot) % self.scenarios,
            _ => rank,
        };
        slot + 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_defaults_and_overrides() {
        assert_eq!(MixSpec::parse("zipf").unwrap(), MixSpec::default());
        let m = MixSpec::parse("zipf:s=1.2,k=4,shift=0.5").unwrap();
        assert_eq!(m.skew, 1.2);
        assert_eq!(m.ranks, 4);
        assert_eq!(m.shift_frac, Some(0.5));
        assert_eq!(m.label(), "zipf:s=1.2,k=4,shift=0.5");
    }

    #[test]
    fn parse_rejects_malformed_specs() {
        assert!(MixSpec::parse("uniform").is_err());
        assert!(MixSpec::parse("zipfs=1").is_err());
        assert!(MixSpec::parse("zipf:s").is_err());
        assert!(MixSpec::parse("zipf:q=3").is_err());
        assert!(MixSpec::parse("zipf:k=0").is_err());
        assert!(MixSpec::parse("zipf:shift=1.5").is_err());
    }

    #[test]
    fn sampler_stays_in_scenario_range() {
        let spec = MixSpec::parse("zipf:s=1.1,k=20").unwrap();
        let s = MixSampler::new(&spec, 5, 1000.0); // ranks clamp to 4
        let mut rng = Pcg32::new(5, 11);
        for i in 0..500 {
            let scen = s.scenario_at(i as f64 * 2.0, &mut rng);
            assert!((1..=4).contains(&scen), "scenario {scen}");
        }
    }

    #[test]
    fn shift_rotates_the_hot_scenario() {
        let spec = MixSpec::parse("zipf:s=2.0,k=2,shift=0.5").unwrap();
        let s = MixSampler::new(&spec, 9, 1000.0);
        let mut rng = Pcg32::new(9, 13);
        let hot_of = |t: f64, rng: &mut Pcg32| {
            let mut counts = [0usize; 9];
            for _ in 0..2000 {
                counts[s.scenario_at(t, rng)] += 1;
            }
            (0..9).max_by_key(|&i| counts[i]).unwrap()
        };
        let before = hot_of(100.0, &mut rng);
        let after = hot_of(600.0, &mut rng);
        assert_eq!(before, 1, "rank 0 maps to scenario 1 before the shift");
        assert_eq!(after, 1 + 8 / 2, "hot rank rotated by half the ring");
    }
}
