//! Seeded, deterministic **open-loop** workload generators.
//!
//! [`crate::data::arrival::arrivals`] answers "spread `n` requests over
//! the horizon" — it rescales whatever gaps it drew so the stream always
//! spans `[0, horizon)`, which makes the *offered rate* a constant
//! `n / horizon` regardless of the distribution.  That is a closed-ish
//! trace: it can shape burstiness but cannot sweep load, so the system
//! can never be pushed past saturation.
//!
//! The generators here are the opposite contract (MLPerf-style open
//! loop): the caller configures an **offered rate** and timestamps are
//! emitted independently of completions — no rescaling, no coupling to
//! service times.  The request *count* is emergent (`≈ rate × horizon`)
//! and the queue is allowed to grow without bound, which is exactly what
//! [`crate::load::capacity`] needs to find the latency-vs-throughput
//! knee.
//!
//! Four gap processes, all driven by one [`Pcg32`] stream so a run is
//! exactly reproducible from `(spec, seed)`:
//!
//! * **poisson** — exponential gaps at the offered rate (the paper's
//!   default arrival model);
//! * **bursty** — Markov-modulated on/off: exponential dwells alternate
//!   between a hi-rate and a lo-rate state, duty-weighted to the offered
//!   mean rate;
//! * **diurnal** — inhomogeneous Poisson with a sinusoidal rate envelope
//!   over the horizon (one full day-cycle), realized by thinning against
//!   the peak rate; peak/trough ratio is
//!   `(1 + DIURNAL_AMPLITUDE) / (1 - DIURNAL_AMPLITUDE)`;
//! * **pareto** — heavy-tailed Pareto gaps (tail index
//!   [`PARETO_ALPHA`], infinite variance) scaled so the *mean* gap is
//!   `1 / rate`.

use crate::data::stream::{Event, EventKind, Stream};
use crate::rng::Pcg32;

use super::mix::{MixSampler, MixSpec};

/// Mean dwell of each bursty on/off state, virtual seconds.
pub const BURSTY_DWELL_MEAN_S: f64 = 5.0;
/// Bursty hi-state rate multiplier (lo-state gets `2 - hi` so the
/// duty-weighted mean over equal expected dwells is the offered rate).
pub const BURSTY_HI_FACTOR: f64 = 1.8;
/// Diurnal envelope amplitude `a`: rate swings `offered * (1 ± a)`, so
/// the configured peak/trough ratio is `(1 + a) / (1 - a)` = 4.
pub const DIURNAL_AMPLITUDE: f64 = 0.6;
/// Pareto tail index (1 < α < 2: finite mean, infinite variance).
pub const PARETO_ALPHA: f64 = 1.8;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum WorkloadKind {
    Poisson,
    Bursty,
    Diurnal,
    Pareto,
}

/// Single source of truth for the CLI name ↔ kind pairing — `parse` and
/// `name` both read it, so a new variant cannot drift between them (the
/// fix `data/arrival.rs` also adopts in this PR).
const KINDS: [(&str, WorkloadKind); 4] = [
    ("poisson", WorkloadKind::Poisson),
    ("bursty", WorkloadKind::Bursty),
    ("diurnal", WorkloadKind::Diurnal),
    ("pareto", WorkloadKind::Pareto),
];

impl WorkloadKind {
    pub fn parse(s: &str) -> Option<WorkloadKind> {
        let lower = s.to_ascii_lowercase();
        KINDS.iter().find(|(n, _)| *n == lower).map(|&(_, k)| k)
    }

    pub fn name(&self) -> &'static str {
        KINDS
            .iter()
            .find(|(_, k)| k == self)
            .map(|&(n, _)| n)
            .unwrap_or("unknown")
    }

    /// Every kind, in table order (repro sweeps iterate this).
    pub fn all() -> [WorkloadKind; 4] {
        [
            WorkloadKind::Poisson,
            WorkloadKind::Bursty,
            WorkloadKind::Diurnal,
            WorkloadKind::Pareto,
        ]
    }
}

/// An open-loop workload: gap process + offered rate (+ optional scenario
/// mix and probe window).  Carried on [`crate::sim::RunConfig`] as
/// `workload: Option<WorkloadSpec>`; `None` — the default — keeps the
/// closed-ish `n_requests` stream byte-identical to every prior PR.
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadSpec {
    pub kind: WorkloadKind,
    /// Offered request rate, requests per virtual second.
    pub offered_rps: f64,
    /// Generate arrivals only over the first `min(window_s, horizon)`
    /// virtual seconds (`None` = the full horizon).  Capacity probes use
    /// this to bound event counts at high offered rates.
    pub window_s: Option<f64>,
    /// Zipf-skewed multi-scenario composition (`--mix`); `None` assigns
    /// each request the scenario active in its arrival window, exactly
    /// like the closed stream does.
    pub mix: Option<MixSpec>,
}

impl WorkloadSpec {
    pub fn poisson(offered_rps: f64) -> WorkloadSpec {
        WorkloadSpec {
            kind: WorkloadKind::Poisson,
            offered_rps,
            window_s: None,
            mix: None,
        }
    }

    /// Append this workload's inference events to `stream` (generated
    /// with `n_requests == 0`, so the closed-stream RNG is untouched)
    /// and re-sort.  The sort is stable and train events were pushed
    /// first, so train-before-inference tie order matches the closed
    /// stream's.  Scenario ids are always in `1..n_scen` — valid indexes
    /// into the benchmark schedule.
    pub fn inject(&self, stream: &mut Stream, n_scen: usize, seed: u64) {
        debug_assert!(n_scen >= 2, "need at least one continual scenario");
        let horizon = match self.window_s {
            Some(w) => w.min(stream.horizon),
            None => stream.horizon,
        };
        let mut rng = Pcg32::new(seed ^ 0x10AD_0001, 29);
        let times =
            open_loop_times(self.kind, self.offered_rps, horizon, &mut rng);
        let window = stream.horizon / (n_scen - 1) as f64;
        let sampler = self
            .mix
            .as_ref()
            .map(|m| MixSampler::new(m, n_scen, stream.horizon));
        for t in times {
            let scenario = match &sampler {
                Some(s) => s.scenario_at(t, &mut rng),
                None => ((t / window) as usize).min(n_scen - 2) + 1,
            };
            stream.events.push(Event {
                t,
                scenario,
                kind: EventKind::Inference,
            });
        }
        stream
            .events
            .sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
    }
}

/// Emit open-loop arrival timestamps: strictly increasing-or-equal,
/// clipped to `[0, horizon)`, **never rescaled** — the empirical rate
/// converges to `offered_rps` but the count is emergent.
pub fn open_loop_times(
    kind: WorkloadKind,
    offered_rps: f64,
    horizon: f64,
    rng: &mut Pcg32,
) -> Vec<f64> {
    if offered_rps <= 0.0 || horizon <= 0.0 {
        return vec![];
    }
    let mut out = Vec::with_capacity((offered_rps * horizon) as usize + 16);
    match kind {
        WorkloadKind::Poisson => {
            let mut t = rng.exponential(offered_rps);
            while t < horizon {
                out.push(t);
                t += rng.exponential(offered_rps);
            }
        }
        WorkloadKind::Bursty => {
            // alternate exponential dwells between a hi- and a lo-rate
            // Poisson state; equal mean dwells duty-weight the pair back
            // to the offered mean.
            let lo_factor = 2.0 - BURSTY_HI_FACTOR;
            let mut t = 0.0;
            let mut hi = true;
            while t < horizon {
                let dwell = rng.exponential(1.0 / BURSTY_DWELL_MEAN_S);
                let end = (t + dwell).min(horizon);
                let rate = offered_rps
                    * if hi { BURSTY_HI_FACTOR } else { lo_factor };
                let mut u = t + rng.exponential(rate);
                while u < end {
                    out.push(u);
                    u += rng.exponential(rate);
                }
                t = end;
                hi = !hi;
            }
        }
        WorkloadKind::Diurnal => {
            // inhomogeneous Poisson by thinning: propose at the peak
            // rate, accept with probability r(t)/peak.  One full cycle
            // over the horizon (peak at horizon/4, trough at 3/4).
            let peak = offered_rps * (1.0 + DIURNAL_AMPLITUDE);
            let mut t = rng.exponential(peak);
            while t < horizon {
                let r = offered_rps
                    * (1.0
                        + DIURNAL_AMPLITUDE
                            * (std::f64::consts::TAU * t / horizon).sin());
                if rng.f64() * peak < r {
                    out.push(t);
                }
                t += rng.exponential(peak);
            }
        }
        WorkloadKind::Pareto => {
            // gap = xm * U^(-1/α); xm chosen so the mean gap is 1/rate.
            let xm = (PARETO_ALPHA - 1.0) / PARETO_ALPHA / offered_rps;
            let mut t = 0.0;
            loop {
                let u = rng.f64().max(1e-12);
                t += xm / u.powf(1.0 / PARETO_ALPHA);
                if t >= horizon {
                    break;
                }
                out.push(t);
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_name_share_one_table() {
        for k in WorkloadKind::all() {
            assert_eq!(WorkloadKind::parse(k.name()), Some(k));
            assert_eq!(
                WorkloadKind::parse(&k.name().to_ascii_uppercase()),
                Some(k)
            );
        }
        assert_eq!(WorkloadKind::parse("uniform"), None);
    }

    #[test]
    fn open_loop_is_sorted_clipped_and_seed_deterministic() {
        for k in WorkloadKind::all() {
            let mut a = Pcg32::new(7, 3);
            let mut b = Pcg32::new(7, 3);
            let xs = open_loop_times(k, 10.0, 200.0, &mut a);
            let ys = open_loop_times(k, 10.0, 200.0, &mut b);
            assert!(!xs.is_empty(), "{k:?} emitted nothing");
            assert!(
                xs.windows(2).all(|w| w[0] <= w[1]),
                "{k:?} not sorted"
            );
            assert!(xs[0] >= 0.0);
            assert!(*xs.last().unwrap() < 200.0, "{k:?} not clipped");
            assert_eq!(xs.len(), ys.len(), "{k:?} not deterministic");
            assert!(xs.iter().zip(&ys).all(|(x, y)| x == y));
        }
    }

    #[test]
    fn count_is_emergent_not_rescaled() {
        // doubling the offered rate roughly doubles the count — the
        // closed-stream rescale would have pinned it.
        let mut rng = Pcg32::new(3, 9);
        let n1 =
            open_loop_times(WorkloadKind::Poisson, 5.0, 400.0, &mut rng).len();
        let n2 =
            open_loop_times(WorkloadKind::Poisson, 10.0, 400.0, &mut rng).len();
        let ratio = n2 as f64 / n1 as f64;
        assert!((1.6..2.4).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn zero_rate_or_horizon_is_benign() {
        let mut rng = Pcg32::new(1, 1);
        assert!(open_loop_times(WorkloadKind::Poisson, 0.0, 100.0, &mut rng)
            .is_empty());
        assert!(open_loop_times(WorkloadKind::Pareto, 5.0, 0.0, &mut rng)
            .is_empty());
    }
}
