//! Capacity search: binary-search offered RPS for the knee of the
//! latency-vs-throughput curve against an SLO predicate.
//!
//! The production question behind the whole load layer (modelled on the
//! IC scalability harness): *what is the maximum sustainable request
//! rate under the SLO?*  A probe at offered rate `r` is one full
//! simulated run with an open-loop workload pinned to `r`; it **passes**
//! when `latency_p99_ms ≤ slo_ms` and the drop rate is `≤ drop_eps`
//! (drops only happen under explicit shedding knobs, so by default the
//! p99 criterion binds).  The knee is the highest probed rate that
//! passes.
//!
//! # Determinism contract
//!
//! The reported knee is **bit-identical** between sequential and
//! `--jobs N` probe execution (pinned by `tests/load.rs`):
//!
//! * every bisection iteration evaluates a *fixed* fan-out of
//!   [`CapacitySpec::probes_per_iter`] interior points — the fan-out
//!   never depends on the worker count, it only decides how much of the
//!   batch runs concurrently;
//! * each batch goes through [`ParallelSweeper::run_many`], whose
//!   reports are worker-count independent by the sweep contract;
//! * the bracket update walks the batch in ascending-rate order and
//!   stops at the first failure, so a (noise-induced) non-monotone
//!   response cannot invert the bracket;
//! * probe rates are pure f64 arithmetic on the bracket — no RNG, no
//!   wall clock.

use anyhow::{ensure, Result};

use crate::metrics::Report;
use crate::sim::{ParallelSweeper, RunConfig};

/// Search configuration: SLO predicate + RPS bracket + probe schedule.
#[derive(Clone, Debug)]
pub struct CapacitySpec {
    /// Pass while the run's global p99 latency is at or under this.
    pub slo_ms: f64,
    /// Pass while `dropped / (served + dropped)` is at or under this.
    pub drop_eps: f64,
    /// Bracket floor (assumed sustainable; verified by the first batch).
    pub lo_rps: f64,
    /// Bracket ceiling (assumed saturating; verified by the first batch).
    pub hi_rps: f64,
    /// Bisection iterations after the endpoint batch.
    pub iters: usize,
    /// Interior probe points per iteration — a constant fan-out, NOT the
    /// worker count, so the probe schedule (and therefore the knee) is
    /// identical at any `--jobs`.
    pub probes_per_iter: usize,
}

impl Default for CapacitySpec {
    fn default() -> CapacitySpec {
        CapacitySpec {
            slo_ms: 250.0,
            drop_eps: 0.01,
            lo_rps: 0.1,
            hi_rps: 8.0,
            iters: 4,
            probes_per_iter: 3,
        }
    }
}

/// One evaluated probe point.
#[derive(Clone, Debug)]
pub struct CapacityProbe {
    pub offered_rps: f64,
    pub p99_ms: f64,
    pub drop_rate: f64,
    pub served: usize,
    pub dropped: u64,
    pub passed: bool,
}

/// The knee plus the full probe log (evaluation order).
#[derive(Clone, Debug)]
pub struct CapacityResult {
    /// Highest probed rate that met the SLO (0.0 when even `lo_rps`
    /// failed — the bracket floor is already past saturation).
    pub knee_rps: f64,
    pub p99_at_knee_ms: f64,
    pub drop_rate_at_knee: f64,
    /// Lowest probed rate known to fail (`hi_rps` when the ceiling
    /// passed — the bracket never saturated).
    pub bracket_hi_rps: f64,
    /// False when `hi_rps` itself passed: the knee is a bracket
    /// artifact, widen `hi_rps` to find the real one.
    pub saturated: bool,
    pub probes: Vec<CapacityProbe>,
}

/// Drop rate over everything that arrived: `dropped / (served + dropped)`.
pub fn drop_rate(r: &Report) -> f64 {
    let total = r.requests.len() as f64 + r.requests_dropped as f64;
    if total == 0.0 {
        0.0
    } else {
        r.requests_dropped as f64 / total
    }
}

/// The SLO predicate a probe must satisfy.
pub fn slo_pass(r: &Report, spec: &CapacitySpec) -> bool {
    r.latency_p99_ms <= spec.slo_ms && drop_rate(r) <= spec.drop_eps
}

/// Evaluate one batch of offered rates concurrently.  `base.workload`
/// must be `Some`; each probe clones it with the rate overridden.
fn run_probes(
    sw: &ParallelSweeper,
    base: &RunConfig,
    spec: &CapacitySpec,
    rates: &[f64],
) -> Result<Vec<CapacityProbe>> {
    let cfgs: Vec<RunConfig> = rates
        .iter()
        .map(|&rps| {
            let mut c = base.clone();
            if let Some(w) = c.workload.as_mut() {
                w.offered_rps = rps;
            }
            c
        })
        .collect();
    let reports = sw.run_many(&cfgs)?;
    Ok(rates
        .iter()
        .zip(&reports)
        .map(|(&offered_rps, r)| CapacityProbe {
            offered_rps,
            p99_ms: r.latency_p99_ms,
            drop_rate: drop_rate(r),
            served: r.requests.len(),
            dropped: r.requests_dropped,
            passed: slo_pass(r, spec),
        })
        .collect())
}

/// Find the knee of the latency-vs-throughput curve for `base`'s
/// workload.  `base.workload` must be set (the kind/mix/window are kept;
/// only `offered_rps` is swept).
pub fn capacity_search(
    sw: &ParallelSweeper,
    base: &RunConfig,
    spec: &CapacitySpec,
) -> Result<CapacityResult> {
    ensure!(
        base.workload.is_some(),
        "capacity search needs an open-loop workload on the config \
         (--workload)"
    );
    ensure!(spec.lo_rps > 0.0, "bracket floor must be positive");
    ensure!(
        spec.hi_rps > spec.lo_rps,
        "bracket ceiling {} must exceed floor {}",
        spec.hi_rps,
        spec.lo_rps
    );

    // batch 0: validate both endpoints.
    let mut probes = run_probes(sw, base, spec, &[spec.lo_rps, spec.hi_rps])?;
    if !probes[0].passed {
        // the floor already violates the SLO: nothing in the bracket is
        // sustainable.
        let p = probes[0].clone();
        return Ok(CapacityResult {
            knee_rps: 0.0,
            p99_at_knee_ms: p.p99_ms,
            drop_rate_at_knee: p.drop_rate,
            bracket_hi_rps: spec.lo_rps,
            saturated: true,
            probes,
        });
    }
    if probes[1].passed {
        // the ceiling is sustainable: the bracket never saturated.
        let p = probes[1].clone();
        return Ok(CapacityResult {
            knee_rps: spec.hi_rps,
            p99_at_knee_ms: p.p99_ms,
            drop_rate_at_knee: p.drop_rate,
            bracket_hi_rps: spec.hi_rps,
            saturated: false,
            probes,
        });
    }

    let mut lo = spec.lo_rps; // highest rate known to pass
    let mut hi = spec.hi_rps; // lowest rate known to fail
    let mut knee = probes[0].clone();
    let m = spec.probes_per_iter.max(1);
    for _ in 0..spec.iters {
        let rates: Vec<f64> = (1..=m)
            .map(|i| lo + (hi - lo) * i as f64 / (m + 1) as f64)
            .collect();
        let batch = run_probes(sw, base, spec, &rates)?;
        for p in &batch {
            if p.passed {
                lo = p.offered_rps;
                knee = p.clone();
            } else {
                hi = p.offered_rps;
                break;
            }
        }
        probes.extend(batch);
    }
    Ok(CapacityResult {
        knee_rps: lo,
        p99_at_knee_ms: knee.p99_ms,
        drop_rate_at_knee: knee.drop_rate,
        bracket_hi_rps: hi,
        saturated: true,
        probes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(p99: f64, served: usize, dropped: u64) -> Report {
        let mut r = Report {
            latency_p99_ms: p99,
            requests_dropped: dropped,
            ..Report::default()
        };
        for _ in 0..served {
            r.requests.push(crate::metrics::RequestRecord {
                t: 0.0,
                scenario: 1,
                accuracy: 0.5,
                stale_batches: 0,
                latency_s: 0.0,
                batch_requests: 1,
                queue_depth: 0,
                degraded: false,
            });
        }
        r
    }

    #[test]
    fn predicate_binds_on_p99_and_drop_rate() {
        let spec = CapacitySpec { slo_ms: 100.0, drop_eps: 0.05, ..CapacitySpec::default() };
        assert!(slo_pass(&report(90.0, 100, 0), &spec));
        assert!(!slo_pass(&report(110.0, 100, 0), &spec), "p99 over SLO");
        assert!(!slo_pass(&report(90.0, 90, 10), &spec), "10% drops");
        assert!(slo_pass(&report(90.0, 99, 1), &spec), "1% drops pass");
    }

    #[test]
    fn drop_rate_of_empty_report_is_zero() {
        assert_eq!(drop_rate(&Report::default()), 0.0);
    }
}
