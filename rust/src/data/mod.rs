//! Data substrate: synthetic continual-learning benchmarks + arrival
//! processes.
//!
//! The paper evaluates on CORe50 (NC / NICv2-79 / NICv2-391), S-CIFAR-10 and
//! 20News — none of which are available in this environment.  Per DESIGN.md
//! we substitute a seeded Gaussian-prototype generator whose scenario
//! transforms reproduce the two change types the paper studies (new feature
//! patterns; new classes), with the same scenario counts and class schedules
//! as the real benchmarks.

pub mod arrival;
pub mod benchmarks;
pub mod stream;
pub mod synth;

pub use benchmarks::Benchmark;
pub use stream::{Event, EventKind, Stream};
pub use synth::World;
