//! Arrival processes for training data and inference requests.
//!
//! The paper's default is Poisson arrivals for both streams (MLPerf-style
//! [64]); Fig. 14 additionally evaluates uniform and normal inter-arrival
//! distributions and a real-world trace (Video Timeline Tags).  The trace
//! here is a bundled bursty sequence with heavy-tailed gaps that reproduces
//! the burstiness that matters to LazyTune's request-pressure term.

use crate::rng::Pcg32;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArrivalKind {
    Poisson,
    Uniform,
    Normal,
    /// Real-world-shaped bursty trace (Video Timeline Tags stand-in).
    Trace,
}

/// Single source of truth for the CLI name ↔ kind pairing: `parse` and
/// `name` both read this table, so adding a variant is one new row and
/// the two directions cannot drift.
const KINDS: [(&str, ArrivalKind); 4] = [
    ("poisson", ArrivalKind::Poisson),
    ("uniform", ArrivalKind::Uniform),
    ("normal", ArrivalKind::Normal),
    ("trace", ArrivalKind::Trace),
];

impl ArrivalKind {
    pub fn parse(s: &str) -> Option<ArrivalKind> {
        let lower = s.to_ascii_lowercase();
        KINDS.iter().find(|(n, _)| *n == lower).map(|&(_, k)| k)
    }

    pub fn name(&self) -> &'static str {
        KINDS
            .iter()
            .find(|(_, k)| k == self)
            .map(|&(n, _)| n)
            .unwrap_or("unknown")
    }
}

/// Normalized inter-arrival gaps of the bundled bursty trace: bursts of
/// near-zero gaps separated by long idle stretches (heavy tail).  Values
/// are multiples of the mean gap; the generator cycles and rescales.
const TRACE: [f64; 48] = [
    0.05, 0.04, 0.06, 0.05, 0.08, 0.04, 0.05, 3.90, 0.10, 0.07, 0.06, 0.09,
    0.05, 0.04, 6.20, 0.12, 0.06, 0.05, 0.07, 0.04, 0.06, 0.05, 2.70, 0.08,
    0.06, 0.04, 0.09, 0.05, 8.10, 0.11, 0.07, 0.05, 0.04, 0.06, 0.05, 1.90,
    0.08, 0.05, 0.06, 0.04, 0.07, 4.40, 0.09, 0.06, 0.05, 0.08, 0.04, 12.3,
];

/// Generate `n` arrival timestamps over `[0, horizon)` with the given mean
/// spacing pattern.  Timestamps are sorted and clipped to the horizon.
pub fn arrivals(
    kind: ArrivalKind,
    n: usize,
    horizon: f64,
    rng: &mut Pcg32,
) -> Vec<f64> {
    if n == 0 {
        return vec![];
    }
    let mean_gap = horizon / n as f64;
    let mut t = 0.0;
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let gap = match kind {
            ArrivalKind::Poisson => rng.exponential(1.0 / mean_gap),
            ArrivalKind::Uniform => rng.f64() * 2.0 * mean_gap,
            ArrivalKind::Normal => {
                (mean_gap + 0.3 * mean_gap * rng.normal() as f64).max(0.0)
            }
            ArrivalKind::Trace => {
                // cycle the trace with jitter; mean of TRACE is ~1.0
                let base = TRACE[(i + rng.below(4)) % TRACE.len()];
                base * mean_gap * (0.8 + 0.4 * rng.f64())
            }
        };
        t += gap;
        out.push(t);
    }
    // rescale so the stream spans the horizon (keeps request counts
    // comparable across kinds, as in the paper's sensitivity study).
    let last = *out.last().unwrap();
    let scale = horizon / last * 0.999;
    out.iter_mut().for_each(|x| *x *= scale);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn check_basic(kind: ArrivalKind) {
        let mut rng = Pcg32::new(9, 2);
        let xs = arrivals(kind, 200, 1000.0, &mut rng);
        assert_eq!(xs.len(), 200);
        assert!(xs.windows(2).all(|w| w[0] <= w[1]), "{kind:?} not sorted");
        assert!(*xs.last().unwrap() <= 1000.0);
        assert!(xs[0] >= 0.0);
    }

    #[test]
    fn all_kinds_produce_sorted_streams_in_horizon() {
        for k in [
            ArrivalKind::Poisson,
            ArrivalKind::Uniform,
            ArrivalKind::Normal,
            ArrivalKind::Trace,
        ] {
            check_basic(k);
        }
    }

    #[test]
    fn poisson_gaps_have_cv_near_one() {
        let mut rng = Pcg32::new(11, 1);
        let xs = arrivals(ArrivalKind::Poisson, 5000, 5000.0, &mut rng);
        let gaps: Vec<f64> = xs.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!((cv - 1.0).abs() < 0.1, "poisson cv {cv}");
    }

    #[test]
    fn trace_is_burstier_than_poisson() {
        let mut rng = Pcg32::new(12, 1);
        let tr = arrivals(ArrivalKind::Trace, 2000, 2000.0, &mut rng);
        let gaps: Vec<f64> = tr.windows(2).map(|w| w[1] - w[0]).collect();
        let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
        let var = gaps.iter().map(|g| (g - mean).powi(2)).sum::<f64>()
            / gaps.len() as f64;
        let cv = var.sqrt() / mean;
        assert!(cv > 1.5, "trace cv {cv} should exceed poisson's 1.0");
    }

    #[test]
    fn empty_request_stream_ok() {
        let mut rng = Pcg32::new(1, 1);
        assert!(arrivals(ArrivalKind::Poisson, 0, 100.0, &mut rng).is_empty());
    }

    #[test]
    fn parse_and_name_round_trip_through_one_table() {
        for k in [
            ArrivalKind::Poisson,
            ArrivalKind::Uniform,
            ArrivalKind::Normal,
            ArrivalKind::Trace,
        ] {
            assert_eq!(ArrivalKind::parse(k.name()), Some(k));
            assert_eq!(
                ArrivalKind::parse(&k.name().to_ascii_uppercase()),
                Some(k)
            );
        }
        assert_eq!(ArrivalKind::parse("bursty"), None);
    }

    #[test]
    fn output_is_sorted_and_strictly_clipped_for_every_kind() {
        // strictly inside [0, horizon): the rescale multiplies by
        // horizon/last * 0.999, so even the final timestamp lands short
        // of the horizon — for every kind, across several seeds/sizes.
        for k in [
            ArrivalKind::Poisson,
            ArrivalKind::Uniform,
            ArrivalKind::Normal,
            ArrivalKind::Trace,
        ] {
            for (seed, n, horizon) in
                [(1u64, 50usize, 100.0f64), (7, 500, 2500.0), (23, 3, 10.0)]
            {
                let mut rng = Pcg32::new(seed, 4);
                let xs = arrivals(k, n, horizon, &mut rng);
                assert_eq!(xs.len(), n);
                assert!(
                    xs.windows(2).all(|w| w[0] <= w[1]),
                    "{k:?} seed {seed} not sorted"
                );
                assert!(xs[0] >= 0.0, "{k:?} seed {seed} negative start");
                assert!(
                    *xs.last().unwrap() < horizon,
                    "{k:?} seed {seed} last {} not strictly < {horizon}",
                    xs.last().unwrap()
                );
            }
        }
    }
}
