//! Benchmark schedules mirroring the paper's evaluation suite.
//!
//! | paper benchmark | scenarios | change type                    | classes |
//! |-----------------|-----------|--------------------------------|---------|
//! | CORe50 NC       | 9         | +new classes each scenario     | 50      |
//! | CORe50 NICv2-79 | 79        | mixed new-class / new-pattern  | 50      |
//! | CORe50 NICv2-391| 391       | mixed, tiny scenarios          | 50      |
//! | S-CIFAR-10      | 5         | 2 fresh classes per scenario   | 10      |
//! | 20News (NLP)    | 10        | 2 fresh classes per scenario   | 20      |
//!
//! Scenario 1 is the pre-deployment training scenario (the paper assumes the
//! model is "originally well-trained" on it); the continual-learning run
//! covers scenarios 2..N.

use crate::rng::Pcg32;

use super::synth::{Transform, World};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Benchmark {
    /// CORe50 NC: 9 scenarios, new classes.
    Nc,
    /// CORe50 NICv2 with 79 scenarios.
    Nic79,
    /// CORe50 NICv2 with 391 scenarios.
    Nic391,
    /// Split CIFAR-10: 5 scenarios x 2 classes.
    SCifar10,
    /// 20 Newsgroups: 10 scenarios x 2 classes (NLP, bert model).
    News20,
}

impl Benchmark {
    pub fn parse(s: &str) -> Option<Benchmark> {
        Some(match s.to_ascii_lowercase().as_str() {
            "nc" => Benchmark::Nc,
            "nic79" | "nicv2_79" => Benchmark::Nic79,
            "nic391" | "nicv2_391" => Benchmark::Nic391,
            "scifar10" | "s-cifar-10" | "scifar" => Benchmark::SCifar10,
            "news20" | "20news" => Benchmark::News20,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Benchmark::Nc => "NC",
            Benchmark::Nic79 => "NICv2_79",
            Benchmark::Nic391 => "NICv2_391",
            Benchmark::SCifar10 => "S-CIFAR-10",
            Benchmark::News20 => "20News",
        }
    }

    pub fn total_classes(&self) -> usize {
        match self {
            Benchmark::Nc | Benchmark::Nic79 | Benchmark::Nic391 => 50,
            Benchmark::SCifar10 => 10,
            Benchmark::News20 => 20,
        }
    }

    pub fn scenario_count(&self) -> usize {
        match self {
            Benchmark::Nc => 9,
            Benchmark::Nic79 => 79,
            Benchmark::Nic391 => 391,
            Benchmark::SCifar10 => 5,
            Benchmark::News20 => 10,
        }
    }

    /// Training batches arriving per continual-learning scenario.  Scaled
    /// down from the real datasets to keep CPU-PJRT runs tractable while
    /// preserving the saturation dynamics (see EXPERIMENTS.md §Setup).
    pub fn batches_per_scenario(&self) -> usize {
        match self {
            Benchmark::Nc => 30,
            Benchmark::Nic79 => 6,
            Benchmark::Nic391 => 2,
            Benchmark::SCifar10 => 30,
            Benchmark::News20 => 15,
        }
    }

    /// Pre-deployment ("well-trained on the first scenario") steps.
    pub fn warmup_batches(&self) -> usize {
        match self {
            Benchmark::Nc | Benchmark::SCifar10 => 60,
            Benchmark::News20 => 40,
            Benchmark::Nic79 | Benchmark::Nic391 => 60,
        }
    }
}

/// One scenario of the schedule.
#[derive(Clone, Debug)]
pub struct Scenario {
    pub id: usize,
    /// Classes whose data arrives in this scenario.
    pub classes: Vec<usize>,
    /// All classes seen up to and including this scenario.
    pub seen: Vec<usize>,
    /// True if this scenario changes feature patterns (vs only new classes).
    pub new_pattern: bool,
}

/// Full schedule: the world (prototypes + transforms) plus scenarios.
pub struct Schedule {
    pub benchmark: Benchmark,
    pub world: World,
    pub scenarios: Vec<Scenario>,
}

/// Build the deterministic schedule for `(benchmark, seed)`.
pub fn build(benchmark: Benchmark, seed: u64) -> Schedule {
    let classes = benchmark.total_classes();
    let mut world = World::new(seed, classes, 3.0, 1.0);
    let mut rng = Pcg32::new(seed ^ 0xBEEF, 11);
    let n = benchmark.scenario_count();
    let mut scenarios = Vec::with_capacity(n);
    let mut seen: Vec<usize> = vec![];

    match benchmark {
        Benchmark::Nc => {
            // scenario 1: 10 classes; +5 classes in each of scenarios 2..9.
            for s in 0..n {
                let fresh: Vec<usize> = if s == 0 {
                    (0..10).collect()
                } else {
                    (10 + (s - 1) * 5..10 + s * 5).collect()
                };
                seen.extend(&fresh);
                // mild environment drift between sessions
                let strength = if s == 0 { 0.0 } else { 0.15 };
                world.push_transform(Transform::random(&mut rng, strength));
                scenarios.push(Scenario {
                    id: s,
                    classes: fresh,
                    seen: seen.clone(),
                    new_pattern: false,
                });
            }
        }
        Benchmark::Nic79 | Benchmark::Nic391 => {
            // scenario 1: 10 classes; later scenarios are small and mixed:
            // ~30% introduce a new class (until 50), others re-expose seen
            // classes under a new pattern.
            seen.extend(0..10);
            world.push_transform(Transform::identity());
            scenarios.push(Scenario {
                id: 0,
                classes: (0..10).collect(),
                seen: seen.clone(),
                new_pattern: false,
            });
            let mut next_class = 10;
            for s in 1..n {
                let want_new = next_class < classes
                    && (rng.f32() < 0.35 || (classes - next_class) >= (n - s));
                if want_new {
                    let fresh = vec![next_class];
                    next_class += 1;
                    seen.extend(&fresh);
                    world.push_transform(Transform::random(&mut rng, 0.1));
                    scenarios.push(Scenario {
                        id: s,
                        classes: fresh,
                        seen: seen.clone(),
                        new_pattern: false,
                    });
                } else {
                    // new pattern over a subset of seen classes
                    let k = 3.min(seen.len());
                    let mut subset = seen.clone();
                    rng.shuffle(&mut subset);
                    subset.truncate(k);
                    world.push_transform(Transform::random(&mut rng, 0.45));
                    scenarios.push(Scenario {
                        id: s,
                        classes: subset,
                        seen: seen.clone(),
                        new_pattern: true,
                    });
                }
            }
        }
        Benchmark::SCifar10 | Benchmark::News20 => {
            for s in 0..n {
                let fresh = vec![2 * s, 2 * s + 1];
                seen.extend(&fresh);
                world.push_transform(Transform::random(
                    &mut rng,
                    if s == 0 { 0.0 } else { 0.1 },
                ));
                scenarios.push(Scenario {
                    id: s,
                    classes: fresh,
                    seen: seen.clone(),
                    new_pattern: false,
                });
            }
        }
    }

    Schedule { benchmark, world, scenarios }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nc_schedule_adds_five_classes_per_scenario() {
        let s = build(Benchmark::Nc, 1);
        assert_eq!(s.scenarios.len(), 9);
        assert_eq!(s.scenarios[0].classes.len(), 10);
        for sc in &s.scenarios[1..] {
            assert_eq!(sc.classes.len(), 5);
        }
        assert_eq!(s.scenarios[8].seen.len(), 50);
    }

    #[test]
    fn nic_schedules_reach_all_classes() {
        for (b, n) in [(Benchmark::Nic79, 79), (Benchmark::Nic391, 391)] {
            let s = build(b, 3);
            assert_eq!(s.scenarios.len(), n);
            assert_eq!(s.scenarios.last().unwrap().seen.len(), 50);
            assert!(s.scenarios.iter().any(|sc| sc.new_pattern));
            // transforms registered for every scenario
            assert_eq!(s.world.transforms.len(), n);
        }
    }

    #[test]
    fn split_benchmarks_partition_classes() {
        for (b, total) in [(Benchmark::SCifar10, 10), (Benchmark::News20, 20)] {
            let s = build(b, 7);
            let mut all: Vec<usize> =
                s.scenarios.iter().flat_map(|sc| sc.classes.clone()).collect();
            all.sort();
            assert_eq!(all, (0..total).collect::<Vec<_>>());
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = build(Benchmark::Nic79, 42);
        let b = build(Benchmark::Nic79, 42);
        for (x, y) in a.scenarios.iter().zip(&b.scenarios) {
            assert_eq!(x.classes, y.classes);
            assert_eq!(x.new_pattern, y.new_pattern);
        }
    }

    #[test]
    fn parse_names_roundtrip() {
        for b in [
            Benchmark::Nc,
            Benchmark::Nic79,
            Benchmark::Nic391,
            Benchmark::SCifar10,
            Benchmark::News20,
        ] {
            assert_eq!(Benchmark::parse(b.name()), Some(b));
        }
        assert_eq!(Benchmark::parse("bogus"), None);
    }
}
