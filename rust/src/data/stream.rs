//! The merged continual-learning event stream (paper Fig. 1): training
//! batches and inference requests arriving over virtual time, across the
//! benchmark's scenario schedule.
//!
//! Scenario 0 is the pre-deployment training scenario and does not appear in
//! the stream; the continual-learning run covers scenarios `1..N`.  Each
//! scenario occupies a contiguous window of virtual time sized by its batch
//! count; inference requests are spread over the whole horizon.

use crate::rng::Pcg32;

use super::arrival::{arrivals, ArrivalKind};
use super::benchmarks::Benchmark;

/// Mean virtual seconds between training-batch arrivals.
pub const TRAIN_GAP_S: f64 = 20.0;

#[derive(Clone, Copy, Debug, PartialEq)]
pub enum EventKind {
    /// One training batch (16 samples) became available.
    TrainBatch,
    /// One inference request (one test draw) must be served now.
    Inference,
}

#[derive(Clone, Copy, Debug)]
pub struct Event {
    pub t: f64,
    pub scenario: usize,
    pub kind: EventKind,
}

/// The full, pre-generated event stream for one run.
#[derive(Clone, Debug)]
pub struct Stream {
    pub events: Vec<Event>,
    /// start time of each scenario window (index = scenario id; entry 0 is
    /// the deployment time = 0.0 for scenario 1).
    pub scenario_starts: Vec<f64>,
    pub horizon: f64,
}

impl Stream {
    /// Build the stream: `n_requests` inference requests over the horizon,
    /// training batches per scenario per the benchmark schedule.
    pub fn generate(
        benchmark: Benchmark,
        n_requests: usize,
        train_kind: ArrivalKind,
        infer_kind: ArrivalKind,
        seed: u64,
    ) -> Stream {
        let mut rng = Pcg32::new(seed ^ 0xA221, 21);
        let n_scen = benchmark.scenario_count();
        let batches = benchmark.batches_per_scenario();
        let window = batches as f64 * TRAIN_GAP_S;

        let mut events = Vec::new();
        let mut scenario_starts = Vec::with_capacity(n_scen);
        let mut t0 = 0.0;
        for s in 1..n_scen {
            scenario_starts.push(t0);
            let ts = arrivals(train_kind, batches, window, &mut rng);
            for t in ts {
                events.push(Event {
                    t: t0 + t,
                    scenario: s,
                    kind: EventKind::TrainBatch,
                });
            }
            t0 += window;
        }
        let horizon = t0;

        let req_times = arrivals(infer_kind, n_requests, horizon, &mut rng);
        for t in req_times {
            // scenario active at time t
            let idx = ((t / window) as usize).min(n_scen - 2);
            events.push(Event {
                t,
                scenario: idx + 1,
                kind: EventKind::Inference,
            });
        }
        events.sort_by(|a, b| a.t.partial_cmp(&b.t).unwrap());
        Stream { events, scenario_starts, horizon }
    }

    pub fn train_batches(&self) -> usize {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::TrainBatch)
            .count()
    }

    pub fn inference_requests(&self) -> usize {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::Inference)
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stream_counts_match_schedule() {
        let s = Stream::generate(
            Benchmark::Nc, 100, ArrivalKind::Poisson, ArrivalKind::Poisson, 7,
        );
        // 8 continual scenarios x 30 batches
        assert_eq!(s.train_batches(), 8 * 30);
        assert_eq!(s.inference_requests(), 100);
    }

    #[test]
    fn events_sorted_and_scenarios_monotone_for_train() {
        let s = Stream::generate(
            Benchmark::SCifar10, 50, ArrivalKind::Poisson, ArrivalKind::Poisson, 3,
        );
        assert!(s.events.windows(2).all(|w| w[0].t <= w[1].t));
        let train_scen: Vec<usize> = s
            .events
            .iter()
            .filter(|e| e.kind == EventKind::TrainBatch)
            .map(|e| e.scenario)
            .collect();
        assert!(train_scen.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(*train_scen.first().unwrap(), 1);
        assert_eq!(*train_scen.last().unwrap(), 4);
    }

    #[test]
    fn request_scenario_matches_window() {
        let s = Stream::generate(
            Benchmark::Nc, 300, ArrivalKind::Uniform, ArrivalKind::Uniform, 11,
        );
        let window = 30.0 * TRAIN_GAP_S;
        for e in s.events.iter().filter(|e| e.kind == EventKind::Inference) {
            let expect = ((e.t / window) as usize).min(7) + 1;
            assert_eq!(e.scenario, expect);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let a = Stream::generate(
            Benchmark::Nc, 40, ArrivalKind::Poisson, ArrivalKind::Poisson, 5,
        );
        let b = Stream::generate(
            Benchmark::Nc, 40, ArrivalKind::Poisson, ArrivalKind::Poisson, 5,
        );
        assert_eq!(a.events.len(), b.events.len());
        for (x, y) in a.events.iter().zip(&b.events) {
            assert_eq!(x.t, y.t);
            assert_eq!(x.kind, y.kind);
        }
    }
}
