//! Synthetic class-prototype world with scenario transforms.
//!
//! Each class `c` is a Gaussian cluster around a prototype `μ_c ∈ R^D`.
//! A *scenario* applies a feature-space transform to every instance drawn
//! while it is active — per-dimension gain (illumination), global shift
//! (background) and a set of Givens rotations (viewpoint/occlusion mixing).
//! This reproduces the paper's two change types:
//!
//! * **new patterns**: same classes, new transform — the deployed model's
//!   decision boundaries are wrong until fine-tuned;
//! * **new classes**: prototypes the head has never been trained on.
//!
//! All draws are deterministic in `(seed, benchmark)` via [`Pcg32`] streams.

use crate::rng::Pcg32;

/// Input feature dimension (matches the models' `d` in the manifest).
pub const DIM: usize = 128;

/// Scenario transform: `x' = gain ⊙ rot(x) + shift`.
#[derive(Clone, Debug)]
pub struct Transform {
    pub gain: Vec<f32>,
    pub shift: Vec<f32>,
    /// Givens rotations: (i, j, cosθ, sinθ).
    pub rotations: Vec<(usize, usize, f32, f32)>,
}

impl Transform {
    pub fn identity() -> Self {
        Transform {
            gain: vec![1.0; DIM],
            shift: vec![0.0; DIM],
            rotations: vec![],
        }
    }

    /// Draw a transform with `strength` in [0, 1] controlling how far it
    /// departs from identity (0 = identity).
    pub fn random(rng: &mut Pcg32, strength: f32) -> Self {
        let gain = (0..DIM)
            .map(|_| 1.0 + strength * 0.5 * (2.0 * rng.f32() - 1.0))
            .collect();
        let shift = (0..DIM).map(|_| strength * 0.4 * rng.normal()).collect();
        let n_rot = (strength * 24.0) as usize;
        let rotations = (0..n_rot)
            .map(|_| {
                let i = rng.below(DIM);
                let mut j = rng.below(DIM);
                if j == i {
                    j = (j + 1) % DIM;
                }
                let theta = strength * 0.8 * (2.0 * rng.f32() - 1.0);
                (i, j, theta.cos(), theta.sin())
            })
            .collect();
        Transform { gain, shift, rotations }
    }

    pub fn apply(&self, x: &mut [f32]) {
        for &(i, j, c, s) in &self.rotations {
            let (xi, xj) = (x[i], x[j]);
            x[i] = c * xi - s * xj;
            x[j] = s * xi + c * xj;
        }
        for d in 0..DIM {
            x[d] = self.gain[d] * x[d] + self.shift[d];
        }
    }
}

/// The synthetic data world: prototypes + per-scenario transforms.
#[derive(Clone, Debug)]
pub struct World {
    pub classes: usize,
    pub noise: f32,
    protos: Vec<Vec<f32>>, // classes x DIM
    pub transforms: Vec<Transform>,
    sampler: Pcg32,
}

impl World {
    /// `separation` scales prototype norms relative to noise; 2.5–3.5 gives
    /// the fast-then-saturating accuracy recovery curves seen in Fig. 4.
    pub fn new(seed: u64, classes: usize, separation: f32, noise: f32) -> Self {
        let mut root = Pcg32::new(seed, 0xDA7A);
        let mut protos = Vec::with_capacity(classes);
        for _ in 0..classes {
            let mut mu: Vec<f32> = (0..DIM).map(|_| root.normal()).collect();
            let norm = mu.iter().map(|v| v * v).sum::<f32>().sqrt();
            let scale = separation * noise / norm * (DIM as f32).sqrt() * 0.35;
            mu.iter_mut().for_each(|v| *v *= scale);
            protos.push(mu);
        }
        let sampler = root.fork(0x5A11);
        World { classes, noise, protos, transforms: vec![], sampler }
    }

    /// Register scenario transforms (index = scenario id).
    pub fn push_transform(&mut self, t: Transform) {
        self.transforms.push(t);
    }

    /// Sampler RNG state (the world's only mutable state after build —
    /// prototypes and transforms are fixed once the schedule registers
    /// them).  Checkpointing saves this pair; everything else regenerates
    /// deterministically from `(seed, benchmark)`.
    pub fn sampler_state(&self) -> (u64, u64) {
        self.sampler.state()
    }

    /// Restore the sampler RNG to a checkpointed state.
    pub fn set_sampler_state(&mut self, state: u64, inc: u64) {
        self.sampler = Pcg32::from_state(state, inc);
    }

    /// Draw one sample of class `c` under scenario `s`'s transform.
    pub fn sample_into(&mut self, c: usize, s: usize, out: &mut [f32]) {
        debug_assert_eq!(out.len(), DIM);
        let proto = &self.protos[c];
        for d in 0..DIM {
            out[d] = proto[d] + self.noise * self.sampler.normal();
        }
        self.transforms[s.min(self.transforms.len() - 1)].apply(out);
    }

    /// Draw a batch: `classes_avail` restricts label draws; returns
    /// (features row-major [n, DIM], labels).
    pub fn batch(
        &mut self,
        n: usize,
        scenario: usize,
        classes_avail: &[usize],
    ) -> (Vec<f32>, Vec<i32>) {
        let mut xs = vec![0.0f32; n * DIM];
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let c = classes_avail[self.sampler.below(classes_avail.len())];
            ys.push(c as i32);
            // `xs` is a local: the row borrow is disjoint from `self`, so
            // samples are written in place (no per-sample scratch Vec).
            self.sample_into(c, scenario, &mut xs[i * DIM..(i + 1) * DIM]);
        }
        (xs, ys)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_world() {
        let mut w1 = World::new(5, 10, 3.0, 1.0);
        let mut w2 = World::new(5, 10, 3.0, 1.0);
        w1.push_transform(Transform::identity());
        w2.push_transform(Transform::identity());
        let (x1, y1) = w1.batch(8, 0, &[0, 1, 2]);
        let (x2, y2) = w2.batch(8, 0, &[0, 1, 2]);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn classes_respect_available_set() {
        let mut w = World::new(1, 20, 3.0, 1.0);
        w.push_transform(Transform::identity());
        let (_, ys) = w.batch(64, 0, &[3, 7]);
        assert!(ys.iter().all(|&y| y == 3 || y == 7));
        assert!(ys.contains(&3) && ys.contains(&7));
    }

    #[test]
    fn prototypes_are_linearly_separable_at_this_noise() {
        // nearest-prototype classification on raw draws should be strong;
        // if this fails the models can never learn the stream.
        let mut w = World::new(9, 10, 3.0, 1.0);
        w.push_transform(Transform::identity());
        let (xs, ys) = w.batch(200, 0, &(0..10).collect::<Vec<_>>());
        let mut correct = 0;
        for i in 0..200 {
            let x = &xs[i * DIM..(i + 1) * DIM];
            let mut best = (f32::INFINITY, 0usize);
            for c in 0..10 {
                let d: f32 = w.protos[c]
                    .iter()
                    .zip(x)
                    .map(|(p, v)| (p - v) * (p - v))
                    .sum();
                if d < best.0 {
                    best = (d, c);
                }
            }
            if best.1 == ys[i] as usize {
                correct += 1;
            }
        }
        assert!(correct > 170, "nearest-proto acc {correct}/200");
    }

    #[test]
    fn transform_changes_distribution() {
        let mut w = World::new(2, 5, 3.0, 1.0);
        w.push_transform(Transform::identity());
        let mut rng = Pcg32::new(77, 3);
        w.push_transform(Transform::random(&mut rng, 0.8));
        let mut a = vec![0.0; DIM];
        let mut b = vec![0.0; DIM];
        w.sample_into(0, 0, &mut a);
        w.sample_into(0, 1, &mut b);
        let dist: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(dist > 1.0, "transform too weak: {dist}");
    }

    #[test]
    fn identity_transform_is_noop() {
        let t = Transform::identity();
        let mut x: Vec<f32> = (0..DIM).map(|i| i as f32).collect();
        let orig = x.clone();
        t.apply(&mut x);
        assert_eq!(x, orig);
    }

    #[test]
    fn rotation_preserves_norm() {
        let mut rng = Pcg32::new(4, 4);
        let mut t = Transform::random(&mut rng, 1.0);
        // strip gain/shift, keep rotations only
        t.gain = vec![1.0; DIM];
        t.shift = vec![0.0; DIM];
        let mut x: Vec<f32> = (0..DIM).map(|_| rng.normal()).collect();
        let n0: f32 = x.iter().map(|v| v * v).sum();
        t.apply(&mut x);
        let n1: f32 = x.iter().map(|v| v * v).sum();
        assert!((n0 - n1).abs() / n0 < 1e-4);
    }
}
