//! End-to-end table/figure regeneration — one harness per paper artifact
//! (DESIGN.md experiment index).  This is the `cargo bench` entry the
//! Makefile's `bench` target runs; it executes the *fast* profile (1 seed,
//! reduced request count) so a full sweep finishes on a laptop-class CPU.
//! For paper-grade numbers run `etuner repro all --seeds 1,2,3,4,5`.
//!
//! Set `ETUNER_BENCH_FULL=1` for the full default profile and
//! `ETUNER_JOBS=N` to bound the sweep worker count (default: all cores).

use etuner::repro::experiments::{self, ReproOpts};
use etuner::runtime::Backend;
use etuner::sim::ParallelSweeper;
use etuner::testkit;

fn main() -> anyhow::Result<()> {
    let full = std::env::var_os("ETUNER_BENCH_FULL").is_some();
    let opts = ReproOpts {
        seeds: if full { vec![1, 2] } else { vec![1] },
        n_requests: if full { 200 } else { 120 },
        results_dir: "results".into(),
    };
    let jobs = std::env::var("ETUNER_JOBS")
        .ok()
        .and_then(|j| j.parse().ok())
        .unwrap_or_else(ParallelSweeper::default_jobs);
    // auto backend: pjrt over the artifacts when executable here, else
    // the pure-rust reference executor (tables regenerate on any machine).
    let sw = ParallelSweeper::from_dir(testkit::artifacts_dir(), jobs)?;
    eprintln!("[tables] backend: {}", sw.backend().name());
    let t0 = std::time::Instant::now();
    for (id, desc) in experiments::list() {
        if id == "fig9" || id == "tab2" || id == "fig10" {
            continue; // emitted together with fig8 / tab3
        }
        println!("\n##### {id}: {desc}");
        let t = std::time::Instant::now();
        experiments::run_experiment(&sw, id, &opts)?;
        println!("##### {id} done in {:.1}s", t.elapsed().as_secs_f64());
    }
    println!("\nall tables/figures regenerated in {:.1}s", t0.elapsed().as_secs_f64());
    Ok(())
}
