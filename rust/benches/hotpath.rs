//! Hot-path microbenchmarks (dependency-free harness; criterion is not
//! available offline).  These are the §Perf L3 numbers in EXPERIMENTS.md:
//!
//!   * train-step latency          (execute + θ marshalling)
//!   * inference latency           (the request-path cost), with the
//!     session θ-buffer cache warm vs force-invalidated
//!   * CKA probe                   (SimFreeze's periodic overhead)
//!   * θ marshal round-trip        (host-side copy cost)
//!   * serving-engine throughput   (cross-request batching vs one execute
//!     per request), twice: a host-side row-wise stand-in executor (the
//!     pre-backend series, kept for cross-PR continuity) and the **really
//!     executing** refcpu backend
//!   * gemm kernel series          (PR 4): packed execution core vs the
//!     naive oracle — fwd/bwd at builtin-family infer and train shapes,
//!     steady-state (cached panels) and pack-inclusive, plus the QAT
//!     fused-quantize pack vs per-call full-tensor fake-quant
//!   * coordinator-only components (NNLS fit, OOD observe, stream gen)
//!
//! `ETUNER_BENCH_FILTER=<key>` runs only matching sections (keys:
//! serving, gemm, refcpu, pjrt, coordinator) — `make bench-gemm` uses it
//! for the isolated kernel series.
//!
//! Run: `make bench` / `cargo bench --bench hotpath`.  The refcpu series
//! run on every machine — no artifacts, no XLA toolchain — so CI
//! environments regenerate *executing* bench numbers, not just host-side
//! pack/scatter timings.  When artifacts are built, the same model series
//! additionally run through the PJRT backend under their original labels.
//! Results are also written as JSON (mean/min/max per benchmark) to
//! `$ETUNER_BENCH_OUT` (default `BENCH_hotpath.json`) so the perf
//! trajectory is trackable across PRs (`make bench-snapshot` archives the
//! per-PR copy under `bench_history/`).

use std::collections::BTreeMap;

use etuner::coordinator::{curve, EnergyOod};
use etuner::cost::flops::FreezeState;
use etuner::data::arrival::ArrivalKind;
use etuner::data::benchmarks::Benchmark;
use etuner::data::stream::Stream;
use etuner::json::Json;
use etuner::model::ModelSession;
use etuner::rng::Pcg32;
use etuner::runtime::Backend;
use etuner::serve::{
    batcher::span_rows, admission::Fifo, AdaptiveBatcher, QueuedRequest,
    RequestQueue,
};
use etuner::testkit::{self, bench};

/// Train/infer/probe series for one backend; `tag` prefixes the labels
/// ("" keeps the historical pjrt label namespace).
fn model_series(
    be: &dyn Backend,
    tag: &str,
    rng: &mut Pcg32,
    report: &mut dyn FnMut(&str, (f64, f64, f64)),
) -> anyhow::Result<()> {
    for model in ["res50", "mbv2", "deit", "bert"] {
        let sess = ModelSession::new(be, model)?;
        let mut p = sess.theta0()?;
        let d = sess.m.d;
        let x: Vec<f32> =
            (0..sess.m.batch_train * d).map(|_| rng.normal()).collect();
        let y: Vec<i32> =
            (0..sess.m.batch_train).map(|_| (rng.next_u32() % 4) as i32).collect();
        let fs = FreezeState::none(sess.m.units);
        report(
            &format!("{tag}{model}: train_step (k=0)"),
            bench(3, 20, || {
                sess.train_step(&mut p, &x, &y, &fs).unwrap();
            }),
        );
        // prefix-truncated variant: the backprop saving under freezing
        let mut fs_k = FreezeState::none(sess.m.units);
        for u in 0..sess.m.units - 2 {
            fs_k.frozen[u] = true;
        }
        report(
            &format!("{tag}{model}: train_step (k=max)"),
            bench(3, 20, || {
                sess.train_step(&mut p, &x, &y, &fs_k).unwrap();
            }),
        );
        let xi: Vec<f32> =
            (0..sess.m.batch_infer * d).map(|_| rng.normal()).collect();
        // θ unchanged between calls: after the first marshal every infer
        // reuses the session's cached θ buffer (the serving hot path).
        report(
            &format!("{tag}{model}: infer warm θ-cache (b {})", sess.m.batch_infer),
            bench(3, 20, || {
                sess.infer(&p, &xi).unwrap();
            }),
        );
        // force-invalidated: bump the parameter generation each call so θ
        // is re-marshalled every time (the seed's per-request cost).
        report(
            &format!("{tag}{model}: infer cold θ-cache (b {})", sess.m.batch_infer),
            bench(3, 20, || {
                p.theta_mut();
                sess.infer(&p, &xi).unwrap();
            }),
        );
        eprintln!(
            "  [{tag}{model}] θ marshals {} / cache hits {}",
            sess.theta_marshal_count(),
            sess.theta_cache_hit_count()
        );
    }

    // SimFreeze probe: features + per-layer CKA
    let sess = ModelSession::new(be, "res50")?;
    let p = sess.theta0()?;
    let probe: Vec<f32> = (0..sess.m.batch_probe * sess.m.d)
        .map(|_| rng.normal())
        .collect();
    let feats = sess.features(&p, &probe)?;
    report(
        &format!("{tag}res50: features probe"),
        bench(3, 20, || {
            sess.features(&p, &probe).unwrap();
        }),
    );
    // the unprefixed (pjrt) series keeps its exact historical JSON keys
    // so bench_history cross-PR diffs keep tracking it.
    let cka_label = if tag.is_empty() {
        "res50: cka one layer (pallas)".to_string()
    } else {
        format!("{tag}res50: cka one layer")
    };
    report(
        &cka_label,
        bench(3, 20, || {
            sess.cka_layer(&feats, &feats, 4).unwrap();
        }),
    );

    // θ marshalling alone (no execute): host -> backend buffer -> host
    let theta = p.theta().to_vec();
    let marshal_label = if tag.is_empty() {
        "theta literal roundtrip (res50)".to_string()
    } else {
        format!("{tag}theta marshal roundtrip (res50)")
    };
    report(
        &marshal_label,
        bench(3, 50, || {
            let v = be.marshal_f32(&theta, &[theta.len()]).unwrap();
            let _ = v.read_f32().unwrap();
        }),
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    println!("{:<38} {:>9} {:>9} {:>9}", "benchmark", "mean_ms", "min_ms", "max_ms");
    let mut results: Vec<(String, (f64, f64, f64))> = Vec::new();
    let mut report = |name: &str, (mean, min, max): (f64, f64, f64)| {
        println!("{name:<38} {mean:>9.3} {min:>9.3} {max:>9.3}");
        results.push((name.to_string(), (mean, min, max)));
    };

    // `ETUNER_BENCH_FILTER=gemm` (etc.) runs only matching sections —
    // `make bench-gemm` uses it for the isolated kernel series.
    let filter = std::env::var("ETUNER_BENCH_FILTER").ok();
    let section =
        |key: &str| -> bool { filter.as_deref().map_or(true, |f| key.contains(f)) };

    let mut rng = Pcg32::new(42, 1);

    // ---- serving engine: cross-request batching throughput (host-side) ----
    // A fixed-shape execute computes all `CAPACITY` rows whether they hold
    // one 8-row request or eight, so batched serving amortizes the
    // full-batch cost; the unbatched series pays it once per request.
    if section("serving") {
        const D: usize = 128;
        const CLASSES: usize = 50;
        const CAPACITY: usize = 64;
        const ROWS: usize = 8;
        const N_REQ: usize = 256;
        let w: Vec<f32> = (0..D * CLASSES).map(|_| rng.normal() * 0.1).collect();
        let execute = |x: &[f32], out: &mut Vec<f32>| {
            out.clear();
            out.resize(CAPACITY * CLASSES, 0.0);
            for r in 0..CAPACITY {
                let row = &x[r * D..(r + 1) * D];
                let dst = &mut out[r * CLASSES..(r + 1) * CLASSES];
                for (i, &v) in row.iter().enumerate() {
                    let wrow = &w[i * CLASSES..(i + 1) * CLASSES];
                    for (o, &wv) in dst.iter_mut().zip(wrow) {
                        *o += v * wv;
                    }
                }
            }
        };
        let reqs: Vec<QueuedRequest> = (0..N_REQ)
            .map(|i| QueuedRequest {
                arrival_t: i as f64,
                deadline_t: i as f64 + 0.25,
                scenario: 1,
                stale_batches: 0,
                x: (0..ROWS * D).map(|_| rng.normal()).collect(),
                y: vec![0; ROWS],
                rows: ROWS,
            })
            .collect();
        let mut logits: Vec<f32> = Vec::new();
        let mut sink = 0usize;

        // both series pay the same queue build + request clones so the
        // delta is purely executes-per-request
        let unbatched = AdaptiveBatcher::new(CAPACITY, 0.0, D);
        report(
            &format!("serving: 1 req/exec ({N_REQ} reqs)"),
            bench(2, 10, || {
                let mut q = RequestQueue::new();
                for r in &reqs {
                    q.push(r.clone());
                }
                while let Some(r) = q.pop() {
                    let p = unbatched.pack(std::slice::from_ref(&r));
                    execute(&p.x, &mut logits);
                    sink += span_rows(&logits, CLASSES, &p.spans[0]).len();
                }
            }),
        );
        let batched = AdaptiveBatcher::new(CAPACITY, 30.0, D);
        report(
            &format!("serving: batched 8 req/exec ({N_REQ} reqs)"),
            bench(2, 10, || {
                let mut q = RequestQueue::new();
                for r in &reqs {
                    q.push(r.clone());
                }
                while !q.is_empty() {
                    let batch = batched.take_batch(&mut q, &Fifo);
                    let p = batched.pack(&batch);
                    execute(&p.x, &mut logits);
                    for s in &p.spans {
                        sink += span_rows(&logits, CLASSES, s).len();
                    }
                }
            }),
        );
        // pack/scatter bookkeeping alone (no execute): the batcher's own
        // overhead must stay negligible against one artifact execution.
        report(
            &format!("serving: pack+scatter only ({N_REQ} reqs)"),
            bench(2, 10, || {
                let mut q = RequestQueue::new();
                for r in &reqs {
                    q.push(r.clone());
                }
                while !q.is_empty() {
                    let batch = batched.take_batch(&mut q, &Fifo);
                    let p = batched.pack(&batch);
                    for s in &p.spans {
                        sink += span_rows(&p.x, D, s).len();
                    }
                }
            }),
        );
        std::hint::black_box(sink);
    }

    // ---- gemm: packed execution core vs the naive oracle ------------------
    // Shapes from the builtin family (res50: d=128, h=e=64) at the infer
    // and train batch sizes.  `packed` runs on cached panels (the steady
    // state); `packed+pack` includes the per-generation pack cost.
    if section("gemm") {
        use etuner::runtime::refcpu::gemm::{self, Act};
        use etuner::runtime::refcpu::naive;

        let mut sink = 0.0f32;
        let shapes = [
            ("infer embed m64 k128 n64", 64usize, 128usize, 64usize),
            ("train embed m16 k128 n64", 16, 128, 64),
            ("train block m16 k64 n64", 16, 64, 64),
        ];
        for (label, m, k, n) in shapes {
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
            let dout: Vec<f32> = (0..m * n).map(|_| rng.normal()).collect();
            let mut out = vec![0.0f32; m * n];
            report(
                &format!("gemm fwd naive ({label})"),
                bench(3, 30, || {
                    out = naive::dense_fwd(&x, &w, &bias, m, k, n, Act::Relu, false);
                    sink += out[0];
                }),
            );
            let pan = gemm::pack_w(&w, k, n, false);
            report(
                &format!("gemm fwd packed ({label})"),
                bench(3, 30, || {
                    gemm::gemm_fwd(&x, &pan, &bias, m, Act::Relu, &mut out);
                    sink += out[0];
                }),
            );
            report(
                &format!("gemm fwd packed+pack ({label})"),
                bench(3, 30, || {
                    let p = gemm::pack_w(&w, k, n, false);
                    gemm::gemm_fwd(&x, &p, &bias, m, Act::Relu, &mut out);
                    sink += out[0];
                }),
            );
            let mut dx = vec![0.0f32; m * k];
            let mut dw = vec![0.0f32; k * n];
            let mut db = vec![0.0f32; n];
            // like-for-like: both sides run only the dx/dw/db kernels on a
            // precomputed dz (= dout for Act::None) — no forward recompute
            // or tape copies on either side.
            report(
                &format!("gemm bwd naive ({label})"),
                bench(3, 30, || {
                    let a = naive::dx_naive(&dout, &w, m, k, n);
                    let b2 = naive::dw_naive(&x, &dout, m, k, n);
                    let c = naive::db_naive(&dout, m, n);
                    sink += a[0] + b2[0] + c[0];
                }),
            );
            let pt = gemm::pack_wt(&w, k, n, false);
            report(
                &format!("gemm bwd packed ({label})"),
                bench(3, 30, || {
                    gemm::gemm_dx(&dout, &pt, m, &mut dx);
                    dw.iter_mut().for_each(|v| *v = 0.0);
                    db.iter_mut().for_each(|v| *v = 0.0);
                    gemm::gemm_dw_acc(&x, &dout, m, k, n, &mut dw);
                    gemm::db_acc(&dout, m, n, &mut db);
                    sink += dx[0] + dw[0] + db[0];
                }),
            );
        }
        // QAT: per-call full-tensor fake-quant of x and w (naive) vs the
        // fused pack — weights quantized once per generation, x into a
        // reused buffer.
        {
            let (m, k, n) = (16usize, 64usize, 64usize);
            let x: Vec<f32> = (0..m * k).map(|_| rng.normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| rng.normal() * 0.5).collect();
            let bias: Vec<f32> = (0..n).map(|_| rng.normal() * 0.1).collect();
            let mut out = vec![0.0f32; m * n];
            report(
                "gemm qat naive (m16 k64 n64)",
                bench(3, 30, || {
                    out = naive::dense_fwd(&x, &w, &bias, m, k, n, Act::Relu, true);
                    sink += out[0];
                }),
            );
            let panq = gemm::pack_w(&w, k, n, true);
            let mut xq = vec![0.0f32; m * k];
            report(
                "gemm qat packed (m16 k64 n64)",
                bench(3, 30, || {
                    gemm::quantize_into(&x, &mut xq);
                    gemm::gemm_fwd(&xq, &panq, &bias, m, Act::Relu, &mut out);
                    sink += out[0];
                }),
            );
        }
        std::hint::black_box(sink);
    }

    // ---- refcpu: REAL executing serving throughput ------------------------
    // Same batched-vs-unbatched shape, but every execute is a real model
    // forward through the reference backend — the cross-PR-comparable
    // serving series CI can regenerate (`make bench-snapshot`).
    let refcpu = testkit::refcpu_spec().create()?;
    if section("serving") {
        let sess = ModelSession::new(refcpu.as_ref(), "mbv2")?;
        let p = sess.theta0()?;
        let d = sess.m.d;
        let cap = sess.m.batch_infer;
        let rows = cap / 8;
        const N_REQ: usize = 64;
        let reqs: Vec<QueuedRequest> = (0..N_REQ)
            .map(|i| QueuedRequest {
                arrival_t: i as f64,
                deadline_t: i as f64 + 0.25,
                scenario: 1,
                stale_batches: 0,
                x: (0..rows * d).map(|_| rng.normal()).collect(),
                y: vec![0; rows],
                rows,
            })
            .collect();
        let mut sink = 0usize;
        let unbatched = AdaptiveBatcher::new(cap, 0.0, d);
        report(
            &format!("serving: refcpu 1 req/exec ({N_REQ} reqs)"),
            bench(1, 5, || {
                let mut q = RequestQueue::new();
                for r in &reqs {
                    q.push(r.clone());
                }
                while let Some(r) = q.pop() {
                    let packed = unbatched.pack(std::slice::from_ref(&r));
                    let logits = sess.infer(&p, &packed.x).unwrap();
                    sink += logits.argmax_rows().len();
                }
            }),
        );
        let batched = AdaptiveBatcher::new(cap, 30.0, d);
        report(
            &format!("serving: refcpu batched 8 req/exec ({N_REQ} reqs)"),
            bench(1, 5, || {
                let mut q = RequestQueue::new();
                for r in &reqs {
                    q.push(r.clone());
                }
                while !q.is_empty() {
                    let batch = batched.take_batch(&mut q, &Fifo);
                    let packed = batched.pack(&batch);
                    let logits = sess.infer(&p, &packed.x).unwrap();
                    sink += logits.argmax_rows().len();
                }
            }),
        );
        std::hint::black_box(sink);
    }

    // ---- mixed-scenario burst through the full control plane --------------
    // A scenario-interleaved trace (s0,s1,s0,s1,…) driven through the real
    // ServeEngine on the executing refcpu backend.  `bank cap 1` forces the
    // pre-PR-5 economics — a single resident serving θ, so every scenario
    // alternation rebuilds (full-θ copy + head install + marshal + re-pack)
    // — while `bank cap 4` keeps both scenarios' banks resident: after the
    // first iteration's warm-up the BankSet path pays zero rebuilds.
    if section("serving") {
        use etuner::cost::device::DeviceModel;
        use etuner::data::benchmarks::Scenario;
        use etuner::model::Cwr;
        use etuner::serve::{ServeConfig, ServeCtx, ServeEngine};

        let sess = ModelSession::new(refcpu.as_ref(), "mbv2")?;
        let params = sess.theta0()?;
        let mut cwr = Cwr::new(&sess.m);
        cwr.consolidate(&sess.m, &params, &[0, 1]);
        let scenarios = vec![
            Scenario { id: 0, classes: vec![0], seen: vec![0], new_pattern: false },
            Scenario {
                id: 1,
                classes: vec![1],
                seen: vec![0, 1],
                new_pattern: false,
            },
        ];
        let ctx = ServeCtx {
            sess: &sess,
            params: &params,
            cwr: &cwr,
            scenarios: &scenarios,
        };
        let d = sess.m.d;
        let rows = sess.m.batch_infer / 4;
        const N_REQ: usize = 64;
        let reqs: Vec<QueuedRequest> = (0..N_REQ)
            .map(|i| QueuedRequest {
                arrival_t: i as f64,
                deadline_t: i as f64 + 1e9,
                scenario: i % 2,
                stale_batches: 0,
                x: (0..rows * d).map(|_| rng.normal()).collect(),
                y: vec![(i % 2) as i32; rows],
                rows,
            })
            .collect();
        let device = DeviceModel::jetson_nx_15w();
        let mut sink = 0usize;
        for (label, bank_cap) in
            [("single-bank rebuild", 1usize), ("bankset resident", 4)]
        {
            let cfg = ServeConfig {
                batch_window_s: 1e6,
                slo_ms: 1e15,
                rows_per_request: Some(rows),
                bank_capacity: bank_cap,
                ..ServeConfig::default()
            };
            let mut eng = ServeEngine::new(&sess.m, &device, &cfg, false, false);
            report(
                &format!("serving: mixed burst {label} ({N_REQ} reqs)"),
                bench(1, 5, || {
                    for r in &reqs {
                        eng.on_arrival(r.clone());
                    }
                    let events = eng.drain(1e7, &ctx).unwrap();
                    sink += events.len();
                }),
            );
            eprintln!(
                "  [mixed burst {label}] rebuilds {} / hits {} / evictions {}",
                eng.serving_rebuilds(),
                eng.serving_hits(),
                eng.bank_evictions()
            );
        }

        // ---- disabled fault layer: zero-overhead passthrough --------------
        // The same resident-bank burst, but through an explicitly
        // constructed FaultyBackend carrying the empty plan.  Compare
        // against `bankset resident` above: the deltas are noise, proving
        // `FaultPlan::none()` (the default) costs the serving hot path
        // nothing.
        {
            use etuner::runtime::{FaultPlan, FaultyBackend};
            let fb = FaultyBackend::new(refcpu.as_ref(), FaultPlan::none(), 0);
            let sess_f = ModelSession::new(&fb, "mbv2")?;
            let params_f = sess_f.theta0()?;
            let mut cwr_f = Cwr::new(&sess_f.m);
            cwr_f.consolidate(&sess_f.m, &params_f, &[0, 1]);
            let ctx_f = ServeCtx {
                sess: &sess_f,
                params: &params_f,
                cwr: &cwr_f,
                scenarios: &scenarios,
            };
            let cfg = ServeConfig {
                batch_window_s: 1e6,
                slo_ms: 1e15,
                rows_per_request: Some(rows),
                bank_capacity: 4,
                ..ServeConfig::default()
            };
            let mut eng = ServeEngine::new(&sess_f.m, &device, &cfg, false, false);
            report(
                &format!("serving: faults off ({N_REQ} reqs)"),
                bench(1, 5, || {
                    for r in &reqs {
                        eng.on_arrival(r.clone());
                    }
                    let events = eng.drain(1e7, &ctx_f).unwrap();
                    sink += events.len();
                }),
            );
        }

        // ---- tracer: disabled vs recording -------------------------------
        // The identical resident-bank burst through a `Tracer::disabled()`
        // engine (the default everywhere — one inlined None check per
        // record site, zero allocation, see the perf_regression canary)
        // and then through a recording tracer.  The "off" row must sit in
        // the noise band of `bankset resident` above; the "on" row prices
        // what `--trace` costs the serving hot path.
        {
            use etuner::trace::{self, Tracer};
            let cfg = ServeConfig {
                batch_window_s: 1e6,
                slo_ms: 1e15,
                rows_per_request: Some(rows),
                bank_capacity: 4,
                ..ServeConfig::default()
            };
            for (label, tracer) in [
                ("trace off", Tracer::disabled()),
                ("trace on", Tracer::enabled(trace::DEFAULT_CAPACITY)),
            ] {
                let mut eng =
                    ServeEngine::new(&sess.m, &device, &cfg, false, false);
                eng.set_tracer(tracer);
                report(
                    &format!("serving: {label} ({N_REQ} reqs)"),
                    bench(1, 5, || {
                        for r in &reqs {
                            eng.on_arrival(r.clone());
                        }
                        let events = eng.drain(1e7, &ctx).unwrap();
                        sink += events.len();
                    }),
                );
            }
        }

        // ---- fleet burst: N engines behind the affinity router (PR 8) -----
        // The same interleaved burst through `run_pool` (sequential mode,
        // the pool behind `repro fleet`) at fleet sizes 1/2/4.  Stub-safe:
        // the refcpu spec builds one executing backend per engine, no
        // artifacts needed, so the series regenerates in any CI box.
        {
            use etuner::runtime::FaultPlan;
            use etuner::serve::{run_pool, FleetConfig, FleetPoolSpec};
            for n in [1usize, 2, 4] {
                let spec = FleetPoolSpec {
                    backend: testkit::refcpu_spec(),
                    model: "mbv2".into(),
                    device: DeviceModel::jetson_nx_15w(),
                    scenarios: scenarios.clone(),
                    serve: ServeConfig {
                        batch_window_s: 1e6,
                        slo_ms: 1e15,
                        rows_per_request: Some(rows),
                        bank_capacity: 4,
                        ..ServeConfig::default()
                    },
                    fleet: FleetConfig { engines: n, ..FleetConfig::default() },
                    trace: false,
                    faults: FaultPlan::none(),
                    fault_seed: 0,
                };
                report(
                    &format!("serving: fleet N={n} ({N_REQ} reqs)"),
                    bench(1, 3, || {
                        let y = run_pool(&spec, &reqs, 1e7, false).unwrap();
                        sink += y.events.len();
                    }),
                );
            }
        }

        // ---- EDF deep backlog: amortized side-index pop loop (PR 8) -------
        // A deep scrambled-deadline backlog fully drained by repeated
        // earliest-deadline selection.  The naive rescan this replaced was
        // O(n^2) in backlog depth; queue.rs pins the side index
        // bit-identical to the reference scan, this series prices it.
        {
            const DEPTH: usize = 4096;
            report(
                &format!("serving: edf deep backlog ({DEPTH} reqs)"),
                bench(1, 5, || {
                    let mut q = RequestQueue::new();
                    for i in 0..DEPTH {
                        q.push(QueuedRequest {
                            arrival_t: i as f64,
                            deadline_t: ((i * 2654435761) % DEPTH) as f64,
                            scenario: 0,
                            stale_batches: 0,
                            x: vec![0.0],
                            y: vec![0],
                            rows: 1,
                        });
                    }
                    while let Some(i) = q.edf_next_index() {
                        sink += q.remove(i).map_or(0, |r| r.rows);
                    }
                }),
            );
        }
        std::hint::black_box(sink);
    }

    // ---- checkpoint: disabled layer vs every-round durable records --------
    // One full quickstart simulation on the executing refcpu backend, run
    // with checkpointing off (the default — constructs nothing, the exact
    // pre-checkpoint code path) and then with a checkpoint directory at
    // the densest cadence (a snapshot every round).  The "off" row is the
    // zero-overhead claim; the "on" row prices serialization + fsync-free
    // atomic rename per round boundary.
    if section("checkpoint") {
        use etuner::sim::{run_config, RunConfig};
        let mk = || {
            let mut cfg = RunConfig::quickstart("mbv2", Benchmark::Nc);
            cfg.n_requests = 40;
            cfg.seed = 7;
            cfg
        };
        let mut sink = 0usize;
        report(
            "checkpoint: off (40 reqs)",
            bench(1, 3, || {
                let r = run_config(refcpu.as_ref(), mk()).unwrap();
                sink += r.requests.len();
            }),
        );
        let dir = std::env::temp_dir()
            .join(format!("etuner-bench-ckpt-{}", std::process::id()));
        let mut written = 0u64;
        report(
            "checkpoint: every round (40 reqs)",
            bench(1, 3, || {
                let mut cfg = mk();
                cfg.checkpoint.dir = Some(dir.clone());
                let r = run_config(refcpu.as_ref(), cfg).unwrap();
                written = r.checkpoints_written;
                sink += r.requests.len();
            }),
        );
        eprintln!("  [checkpoint on] {written} records per run");
        let _ = std::fs::remove_dir_all(&dir);
        std::hint::black_box(sink);
    }

    // ---- refcpu model series (executes everywhere, CI included) -----------
    if section("refcpu") {
        model_series(refcpu.as_ref(), "refcpu ", &mut rng, &mut report)?;
    }

    // ---- pjrt series under the historical labels (needs artifacts) --------
    if section("pjrt") {
        if let Some(pjrt) = testkit::pjrt_backend_if_available() {
            model_series(pjrt.as_ref(), "", &mut rng, &mut report)?;
        } else {
            eprintln!(
                "pjrt backend unavailable (artifacts not built or no xla \
                 feature); skipping the pjrt series"
            );
        }
    }

    // ---- load layer: open-loop generation + mix + injected run (PR 10) ----
    // Generation and mix assignment are backend-free (pure Pcg32 + f64
    // arithmetic) and must stay negligible against a single execute; the
    // end-to-end row prices a full quickstart simulation fed by an
    // injected open-loop stream on the executing refcpu backend.
    if section("load") {
        use etuner::load::{open_loop_times, MixSampler, MixSpec, WorkloadKind, WorkloadSpec};
        use etuner::sim::{run_config, RunConfig};

        let mut sink = 0usize;
        for kind in WorkloadKind::all() {
            report(
                &format!("load: gen {} (50 rps x 200s)", kind.name()),
                bench(3, 30, || {
                    let mut g = Pcg32::new(11, 29);
                    sink += open_loop_times(kind, 50.0, 200.0, &mut g).len();
                }),
            );
        }
        let spec = MixSpec::parse("zipf:s=1.1,k=8,shift=0.5").unwrap();
        let sampler = MixSampler::new(&spec, 10, 200.0);
        let mut g = Pcg32::new(13, 31);
        let ts = open_loop_times(WorkloadKind::Poisson, 50.0, 200.0, &mut g);
        report(
            &format!("load: zipf mix assign ({} arrivals)", ts.len()),
            bench(3, 30, || {
                let mut r = Pcg32::new(17, 37);
                for &t in &ts {
                    sink += sampler.scenario_at(t, &mut r);
                }
            }),
        );
        report(
            "load: open-loop run (poisson 1.5 rps, 40s window)",
            bench(1, 3, || {
                let mut cfg = RunConfig::quickstart("mbv2", Benchmark::SCifar10);
                cfg.seed = 7;
                cfg.workload = Some(WorkloadSpec {
                    kind: WorkloadKind::Poisson,
                    offered_rps: 1.5,
                    window_s: Some(40.0),
                    mix: None,
                });
                let r = run_config(refcpu.as_ref(), cfg).unwrap();
                sink += r.requests.len();
            }),
        );
        std::hint::black_box(sink);
    }

    // ---- coordinator-only components (backend-free) ----
    if section("coordinator") {
        let pts: Vec<(f64, f64)> =
            (1..40).map(|k| (k as f64, 0.8 - 0.5 / k as f64)).collect();
        report(
            "nnls curve fit (40 points)",
            bench(10, 200, || {
                let _ = curve::fit(&pts);
            }),
        );
        let mut ood = EnergyOod::new();
        let mut i = 0u64;
        report(
            "ood observe",
            bench(10, 200, || {
                for _ in 0..100 {
                    i += 1;
                    ood.observe(-8.0 + (i % 7) as f64 * 0.05);
                }
            }),
        );
        report(
            "stream generate (NIC391, 500 reqs)",
            bench(2, 10, || {
                let _ = Stream::generate(
                    Benchmark::Nic391,
                    500,
                    ArrivalKind::Poisson,
                    ArrivalKind::Poisson,
                    7,
                );
            }),
        );
    }

    write_results(&results)
}

/// Machine-readable trajectory file (tracked across PRs by `make bench`).
fn write_results(results: &[(String, (f64, f64, f64))]) -> anyhow::Result<()> {
    let mut obj = BTreeMap::new();
    for (name, (mean, min, max)) in results {
        let mut entry = BTreeMap::new();
        entry.insert("mean_ms".to_string(), Json::Num(*mean));
        entry.insert("min_ms".to_string(), Json::Num(*min));
        entry.insert("max_ms".to_string(), Json::Num(*max));
        obj.insert(name.clone(), Json::Obj(entry));
    }
    let out = std::env::var("ETUNER_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&out, Json::Obj(obj).to_string())?;
    println!("\nwrote {out}");
    Ok(())
}
