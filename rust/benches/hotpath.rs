//! Hot-path microbenchmarks (dependency-free harness; criterion is not
//! available offline).  These are the §Perf L3 numbers in EXPERIMENTS.md:
//!
//!   * train-step latency          (PJRT execute + θ marshalling)
//!   * inference latency           (the request-path cost), with the
//!     session θ-literal cache warm vs force-invalidated
//!   * CKA probe                   (SimFreeze's periodic overhead)
//!   * θ literal marshalling alone (host-side copy cost)
//!   * coordinator-only components (NNLS fit, OOD observe, stream gen)
//!
//! Run: `make bench` / `cargo bench --bench hotpath` (artifacts required).
//! Results are also written as JSON (mean/min/max per benchmark) to
//! `$ETUNER_BENCH_OUT` (default `BENCH_hotpath.json`) so the perf
//! trajectory is trackable across PRs.

use std::collections::BTreeMap;

use etuner::coordinator::{curve, EnergyOod};
use etuner::cost::flops::FreezeState;
use etuner::data::arrival::ArrivalKind;
use etuner::data::benchmarks::Benchmark;
use etuner::data::stream::Stream;
use etuner::json::Json;
use etuner::model::ModelSession;
use etuner::rng::Pcg32;
use etuner::runtime::{Runtime, TensorF32};
use etuner::testkit::{self, bench};

fn main() -> anyhow::Result<()> {
    if !testkit::artifacts_available() {
        eprintln!("artifacts not built; run `make artifacts` first");
        return Ok(());
    }
    let rt = Runtime::load(testkit::artifacts_dir())?;
    println!("{:<38} {:>9} {:>9} {:>9}", "benchmark", "mean_ms", "min_ms", "max_ms");
    let mut results: Vec<(String, (f64, f64, f64))> = Vec::new();
    let mut report = |name: &str, (mean, min, max): (f64, f64, f64)| {
        println!("{name:<38} {mean:>9.3} {min:>9.3} {max:>9.3}");
        results.push((name.to_string(), (mean, min, max)));
    };

    let mut rng = Pcg32::new(42, 1);
    for model in ["res50", "mbv2", "deit", "bert"] {
        let sess = ModelSession::new(&rt, model)?;
        let mut p = sess.theta0()?;
        let d = sess.m.d;
        let x: Vec<f32> =
            (0..sess.m.batch_train * d).map(|_| rng.normal()).collect();
        let y: Vec<i32> =
            (0..sess.m.batch_train).map(|_| (rng.next_u32() % 4) as i32).collect();
        let fs = FreezeState::none(sess.m.units);
        report(
            &format!("{model}: train_step (k=0)"),
            bench(3, 20, || {
                sess.train_step(&mut p, &x, &y, &fs).unwrap();
            }),
        );
        // prefix-truncated variant: real backprop saving in the artifact
        let mut fs_k = FreezeState::none(sess.m.units);
        for u in 0..sess.m.units - 2 {
            fs_k.frozen[u] = true;
        }
        report(
            &format!("{model}: train_step (k=max)"),
            bench(3, 20, || {
                sess.train_step(&mut p, &x, &y, &fs_k).unwrap();
            }),
        );
        let xi: Vec<f32> =
            (0..sess.m.batch_infer * d).map(|_| rng.normal()).collect();
        // θ unchanged between calls: after the first marshal every infer
        // reuses the session's cached θ literal (the serving hot path).
        report(
            &format!("{model}: infer warm θ-cache (b {})", sess.m.batch_infer),
            bench(3, 20, || {
                sess.infer(&p, &xi).unwrap();
            }),
        );
        // force-invalidated: bump the parameter generation each call so θ
        // is re-marshalled every time (the seed's per-request cost).
        report(
            &format!("{model}: infer cold θ-cache (b {})", sess.m.batch_infer),
            bench(3, 20, || {
                p.theta_mut();
                sess.infer(&p, &xi).unwrap();
            }),
        );
        eprintln!(
            "  [{model}] θ marshals {} / cache hits {}",
            sess.theta_marshal_count(),
            sess.theta_cache_hit_count()
        );
    }

    // SimFreeze probe: features + per-layer CKA
    let sess = ModelSession::new(&rt, "res50")?;
    let p = sess.theta0()?;
    let probe: Vec<f32> = (0..sess.m.batch_probe * sess.m.d)
        .map(|_| rng.normal())
        .collect();
    let feats = sess.features(&p, &probe)?;
    report(
        "res50: features probe",
        bench(3, 20, || {
            sess.features(&p, &probe).unwrap();
        }),
    );
    report(
        "res50: cka one layer (pallas)",
        bench(3, 20, || {
            sess.cka_layer(&feats, &feats, 4).unwrap();
        }),
    );

    // θ marshalling alone (no execute): host->literal->host
    let theta = p.theta().to_vec();
    report(
        "theta literal roundtrip (res50)",
        bench(3, 50, || {
            let t = TensorF32::new(vec![theta.len()], theta.clone());
            let lit = t.to_literal().unwrap();
            let _ = TensorF32::from_literal(lit).unwrap();
        }),
    );

    // coordinator-only components
    let pts: Vec<(f64, f64)> =
        (1..40).map(|k| (k as f64, 0.8 - 0.5 / k as f64)).collect();
    report(
        "nnls curve fit (40 points)",
        bench(10, 200, || {
            let _ = curve::fit(&pts);
        }),
    );
    let mut ood = EnergyOod::new();
    let mut i = 0u64;
    report(
        "ood observe",
        bench(10, 200, || {
            for _ in 0..100 {
                i += 1;
                ood.observe(-8.0 + (i % 7) as f64 * 0.05);
            }
        }),
    );
    report(
        "stream generate (NIC391, 500 reqs)",
        bench(2, 10, || {
            let _ = Stream::generate(
                Benchmark::Nic391,
                500,
                ArrivalKind::Poisson,
                ArrivalKind::Poisson,
                7,
            );
        }),
    );

    // machine-readable trajectory file (tracked across PRs by `make bench`)
    let mut obj = BTreeMap::new();
    for (name, (mean, min, max)) in &results {
        let mut entry = BTreeMap::new();
        entry.insert("mean_ms".to_string(), Json::Num(*mean));
        entry.insert("min_ms".to_string(), Json::Num(*min));
        entry.insert("max_ms".to_string(), Json::Num(*max));
        obj.insert(name.clone(), Json::Obj(entry));
    }
    let out = std::env::var("ETUNER_BENCH_OUT")
        .unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    std::fs::write(&out, Json::Obj(obj).to_string())?;
    println!("\nwrote {out}");
    Ok(())
}
